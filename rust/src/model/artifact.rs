//! Versioned weight artifacts: a self-describing manifest wrapped
//! around the HRRCKPT1 parameter payload — the unit of exchange between
//! training and serving (ROADMAP item 4).
//!
//! Following the manifest-plus-payload design of artcode's RFC 0005,
//! an artifact is one file:
//!
//! ```text
//!   magic "HRRART1\n" | u32 manifest_len | manifest JSON | HRRCKPT1 payload
//! ```
//!
//! The manifest carries everything a consumer needs to decide whether
//! the payload is (a) intact and (b) loadable *before* trusting a single
//! weight: a schema version, a hash of the producing model config,
//! per-tensor FNV-1a checksums over the exact serialized bytes, a
//! whole-payload checksum, and provenance (task, base, optimizer step,
//! final eval). [`Artifact::open`] verifies every checksum and returns a
//! typed [`ArtifactError`] on mismatch, so a corrupt or tampered file is
//! rejected at the door — `Engine::reload` never sees its tensors.
//!
//! Checksums are FNV-1a 64 (dependency-free, deterministic, and plenty
//! for integrity — this is corruption detection, not cryptographic
//! authentication). They are rendered as fixed-width hex strings in the
//! JSON manifest because u64 does not survive a round-trip through f64.

use std::fmt;
use std::path::Path;

use anyhow::{Context, Result};

use crate::hrr::HrrConfig;
use crate::model::params::{tensor_data_bytes, ParamStore};
use crate::runtime::tensor::DType;
use crate::util::json::Json;

/// File magic — 8 bytes, like the payload's `HRRCKPT1`.
pub const ARTIFACT_MAGIC: &[u8; 8] = b"HRRART1\n";

/// Manifest schema understood by this build. Bumped on incompatible
/// manifest changes; [`Artifact::open`] rejects anything newer.
pub const SCHEMA_VERSION: u64 = 1;

/// Typed failure surface of artifact verification. Callers that need to
/// distinguish "file is damaged" from "file is fine but wrong model"
/// match on this (via `anyhow::Error::downcast_ref`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ArtifactError {
    /// Not an artifact at all (wrong magic bytes).
    BadMagic,
    /// Manifest schema newer than this build understands.
    SchemaVersion { found: u64, supported: u64 },
    /// Manifest is structurally invalid JSON / missing required fields.
    Manifest(String),
    /// Payload or a tensor fails its manifest checksum.
    Corrupt { what: String, expected: u64, got: u64 },
    /// Manifest tensor list and payload tensors disagree.
    PayloadMismatch(String),
    /// File truncated relative to its declared lengths.
    Truncated,
}

impl fmt::Display for ArtifactError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ArtifactError::BadMagic => write!(f, "not a HRRART1 artifact (bad magic)"),
            ArtifactError::SchemaVersion { found, supported } => write!(
                f,
                "artifact schema version {found} is newer than supported ({supported})"
            ),
            ArtifactError::Manifest(msg) => write!(f, "invalid artifact manifest: {msg}"),
            ArtifactError::Corrupt { what, expected, got } => write!(
                f,
                "artifact corrupt: {what} checksum {got:016x} does not match manifest \
                 {expected:016x}"
            ),
            ArtifactError::PayloadMismatch(msg) => {
                write!(f, "artifact payload does not match its manifest: {msg}")
            }
            ArtifactError::Truncated => write!(f, "artifact file is truncated"),
        }
    }
}

impl std::error::Error for ArtifactError {}

/// FNV-1a 64-bit over a byte stream.
#[derive(Clone, Copy)]
pub struct Fnv64(u64);

impl Fnv64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;

    pub fn new() -> Fnv64 {
        Fnv64(Self::OFFSET)
    }

    pub fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(Self::PRIME);
        }
    }

    pub fn finish(self) -> u64 {
        self.0
    }
}

impl Default for Fnv64 {
    fn default() -> Self {
        Fnv64::new()
    }
}

/// One-shot FNV-1a 64 of a byte slice.
pub fn fnv64(bytes: &[u8]) -> u64 {
    let mut h = Fnv64::new();
    h.update(bytes);
    h.finish()
}

/// Checksum of a tensor's serialized data section — the exact LE bytes
/// the HRRCKPT1 serializer writes for it.
fn tensor_fnv64(t: &crate::runtime::tensor::Tensor) -> u64 {
    let mut h = Fnv64::new();
    let _ = tensor_data_bytes::<()>(t, |chunk| {
        h.update(chunk);
        Ok(())
    });
    h.finish()
}

/// Where an artifact came from: enough to answer "which training run
/// produced these weights, and how good were they" without opening the
/// training logs.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Provenance {
    /// Task name (e.g. `ember`).
    pub task: String,
    /// Full program base (e.g. `ember_hrrformer_small_T256_B8`).
    pub base: String,
    /// Optimizer steps taken when the artifact was written.
    pub step: u32,
    /// Final held-out eval, when one ran: (loss, accuracy).
    pub final_eval: Option<(f32, f32)>,
}

/// Manifest entry for one payload tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
    pub fnv64: u64,
}

/// The parsed artifact manifest (the JSON between magic and payload).
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactManifest {
    pub schema_version: u64,
    /// FNV-1a 64 of the producing config's canonical description —
    /// provenance, not a load gate (reload validates structurally
    /// against each bucket's own spec).
    pub config_hash: u64,
    /// Canonical config description the hash covers (human-readable).
    pub config: String,
    /// Architecture that produced (and can consume) the payload —
    /// `"hrrformer"` or `"hgconv"`. Manifests written before the field
    /// existed parse as `"hrrformer"` (the only architecture back then),
    /// so legacy artifacts stay loadable. `Engine::reload` gates on
    /// this: weights never cross architectures.
    pub arch: String,
    pub payload_len: usize,
    pub payload_fnv: u64,
    pub tensors: Vec<TensorEntry>,
    pub provenance: Provenance,
}

impl ArtifactManifest {
    /// Build a manifest describing `params` as produced by `cfg`.
    pub fn describe(
        cfg: &HrrConfig,
        params: &ParamStore,
        payload: &[u8],
        provenance: Provenance,
    ) -> ArtifactManifest {
        let config = canonical_config(cfg);
        ArtifactManifest {
            schema_version: SCHEMA_VERSION,
            config_hash: fnv64(config.as_bytes()),
            config,
            arch: cfg.arch.as_str().to_string(),
            payload_len: payload.len(),
            payload_fnv: fnv64(payload),
            tensors: params
                .names
                .iter()
                .zip(&params.tensors)
                .map(|(name, t)| TensorEntry {
                    name: name.clone(),
                    shape: t.shape().to_vec(),
                    dtype: t.dtype(),
                    fnv64: tensor_fnv64(t),
                })
                .collect(),
            provenance,
        }
    }

    fn to_json(&self) -> Json {
        let mut prov = vec![
            ("task".to_string(), Json::Str(self.provenance.task.clone())),
            ("base".to_string(), Json::Str(self.provenance.base.clone())),
            ("step".to_string(), Json::Num(self.provenance.step as f64)),
        ];
        if let Some((loss, acc)) = self.provenance.final_eval {
            prov.push((
                "final_eval".to_string(),
                Json::Obj(
                    [
                        ("loss".to_string(), Json::Num(loss as f64)),
                        ("acc".to_string(), Json::Num(acc as f64)),
                    ]
                    .into_iter()
                    .collect(),
                ),
            ));
        }
        let tensors = self
            .tensors
            .iter()
            .map(|t| {
                Json::Obj(
                    [
                        ("name".to_string(), Json::Str(t.name.clone())),
                        (
                            "shape".to_string(),
                            Json::Arr(t.shape.iter().map(|&d| Json::Num(d as f64)).collect()),
                        ),
                        ("dtype".to_string(), Json::Str(dtype_str(t.dtype).to_string())),
                        ("fnv64".to_string(), Json::Str(format!("{:016x}", t.fnv64))),
                    ]
                    .into_iter()
                    .collect(),
                )
            })
            .collect();
        Json::Obj(
            [
                ("schema_version".to_string(), Json::Num(self.schema_version as f64)),
                ("config_hash".to_string(), Json::Str(format!("{:016x}", self.config_hash))),
                ("config".to_string(), Json::Str(self.config.clone())),
                ("arch".to_string(), Json::Str(self.arch.clone())),
                ("payload_len".to_string(), Json::Num(self.payload_len as f64)),
                ("payload_fnv".to_string(), Json::Str(format!("{:016x}", self.payload_fnv))),
                ("tensors".to_string(), Json::Arr(tensors)),
                ("provenance".to_string(), Json::Obj(prov.into_iter().collect())),
            ]
            .into_iter()
            .collect(),
        )
    }

    fn from_json(doc: &Json) -> Result<ArtifactManifest, ArtifactError> {
        let field = |name: &str| {
            doc.get(name).ok_or_else(|| ArtifactError::Manifest(format!("missing '{name}'")))
        };
        let hex = |name: &str, v: &Json| {
            v.as_str()
                .and_then(|s| u64::from_str_radix(s, 16).ok())
                .ok_or_else(|| ArtifactError::Manifest(format!("'{name}' must be a hex string")))
        };
        let schema_version = field("schema_version")?
            .as_i64()
            .and_then(|v| u64::try_from(v).ok())
            .ok_or_else(|| ArtifactError::Manifest("'schema_version' must be a number".into()))?;
        if schema_version > SCHEMA_VERSION {
            return Err(ArtifactError::SchemaVersion {
                found: schema_version,
                supported: SCHEMA_VERSION,
            });
        }
        let config_hash = hex("config_hash", field("config_hash")?)?;
        let config = field("config")?
            .as_str()
            .ok_or_else(|| ArtifactError::Manifest("'config' must be a string".into()))?
            .to_string();
        // pre-arch manifests (schema 1, PR 8 and earlier) could only
        // have been written by the Hrrformer
        let arch = doc
            .get("arch")
            .and_then(Json::as_str)
            .unwrap_or("hrrformer")
            .to_string();
        let payload_len = field("payload_len")?
            .as_usize()
            .ok_or_else(|| ArtifactError::Manifest("'payload_len' must be a number".into()))?;
        let payload_fnv = hex("payload_fnv", field("payload_fnv")?)?;
        let mut tensors = Vec::new();
        for t in field("tensors")?
            .as_arr()
            .ok_or_else(|| ArtifactError::Manifest("'tensors' must be an array".into()))?
        {
            let name = t
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| ArtifactError::Manifest("tensor entry missing 'name'".into()))?
                .to_string();
            let shape = t
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| ArtifactError::Manifest("tensor entry missing 'shape'".into()))?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Option<Vec<_>>>()
                .ok_or_else(|| ArtifactError::Manifest("bad tensor shape".into()))?;
            let dtype = match t.get("dtype").and_then(Json::as_str) {
                Some("f32") => DType::F32,
                Some("i32") => DType::I32,
                Some("u32") => DType::U32,
                other => {
                    return Err(ArtifactError::Manifest(format!("bad tensor dtype {other:?}")))
                }
            };
            let sum = hex(
                "fnv64",
                t.get("fnv64")
                    .ok_or_else(|| ArtifactError::Manifest("tensor entry missing 'fnv64'".into()))?,
            )?;
            tensors.push(TensorEntry { name, shape, dtype, fnv64: sum });
        }
        let prov = field("provenance")?;
        let provenance = Provenance {
            task: prov.get("task").and_then(Json::as_str).unwrap_or_default().to_string(),
            base: prov.get("base").and_then(Json::as_str).unwrap_or_default().to_string(),
            step: prov
                .get("step")
                .and_then(Json::as_i64)
                .and_then(|v| u32::try_from(v).ok())
                .unwrap_or(0),
            final_eval: prov.get("final_eval").and_then(|e| {
                Some((e.get("loss")?.as_f64()? as f32, e.get("acc")?.as_f64()? as f32))
            }),
        };
        Ok(ArtifactManifest {
            schema_version,
            config_hash,
            config,
            arch,
            payload_len,
            payload_fnv,
            tensors,
            provenance,
        })
    }
}

fn dtype_str(d: DType) -> &'static str {
    match d {
        DType::F32 => "f32",
        DType::I32 => "i32",
        DType::U32 => "u32",
    }
}

/// Canonical one-line config description the manifest's `config_hash`
/// covers. Excludes `batch` — the same weights serve any batch shape.
/// The architecture token is appended **only** for non-default
/// architectures, so every Hrrformer hash ever written stays stable.
pub fn canonical_config(cfg: &HrrConfig) -> String {
    let mut desc = format!(
        "task={} vocab={} seq_len={} embed={} mlp_dim={} heads={} layers={} classes={} \
         learned_pos={}",
        cfg.task,
        cfg.vocab,
        cfg.seq_len,
        cfg.embed,
        cfg.mlp_dim,
        cfg.heads,
        cfg.layers,
        cfg.classes,
        cfg.learned_pos
    );
    if cfg.arch != crate::hrr::Arch::Hrrformer {
        desc.push_str(&format!(" arch={}", cfg.arch));
    }
    desc
}

/// A verified artifact: manifest + the parameters decoded from its
/// payload. Constructing one through [`Artifact::open`] /
/// [`Artifact::open_bytes`] implies every checksum passed.
#[derive(Debug, Clone)]
pub struct Artifact {
    pub manifest: ArtifactManifest,
    pub params: ParamStore,
}

impl Artifact {
    /// Serialize `params` (as produced by `cfg`) into a single artifact
    /// file at `path`. Returns the manifest that was written.
    pub fn write(
        path: &Path,
        cfg: &HrrConfig,
        params: &ParamStore,
        provenance: Provenance,
    ) -> Result<ArtifactManifest> {
        let bytes = Self::to_bytes(cfg, params, provenance)?;
        std::fs::write(path, bytes.0).with_context(|| format!("write {}", path.display()))?;
        Ok(bytes.1)
    }

    /// Serialize to in-memory artifact bytes (file image) + manifest.
    pub fn to_bytes(
        cfg: &HrrConfig,
        params: &ParamStore,
        provenance: Provenance,
    ) -> Result<(Vec<u8>, ArtifactManifest)> {
        let payload = params.to_bytes()?;
        let manifest = ArtifactManifest::describe(cfg, params, &payload, provenance);
        let manifest_json = manifest.to_json().to_string();
        let mut out =
            Vec::with_capacity(8 + 4 + manifest_json.len() + payload.len());
        out.extend_from_slice(ARTIFACT_MAGIC);
        out.extend_from_slice(&(manifest_json.len() as u32).to_le_bytes());
        out.extend_from_slice(manifest_json.as_bytes());
        out.extend_from_slice(&payload);
        Ok((out, manifest))
    }

    /// Open + fully verify an artifact file. Any checksum mismatch is a
    /// typed [`ArtifactError`] — a damaged file never yields tensors.
    pub fn open(path: &Path) -> Result<Artifact> {
        let bytes =
            std::fs::read(path).with_context(|| format!("open artifact {}", path.display()))?;
        Self::open_bytes(&bytes).with_context(|| format!("verify artifact {}", path.display()))
    }

    /// Open + fully verify an in-memory artifact image (e.g. an inline
    /// HTTP upload body).
    pub fn open_bytes(bytes: &[u8]) -> Result<Artifact> {
        let art = Self::parse(bytes)?;
        Ok(art)
    }

    fn parse(bytes: &[u8]) -> Result<Artifact, ArtifactError> {
        if bytes.len() < 12 {
            return Err(ArtifactError::Truncated);
        }
        if &bytes[..8] != ARTIFACT_MAGIC {
            return Err(ArtifactError::BadMagic);
        }
        let mlen = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
        let payload_off = 12 + mlen;
        if bytes.len() < payload_off {
            return Err(ArtifactError::Truncated);
        }
        let manifest_json = std::str::from_utf8(&bytes[12..payload_off])
            .map_err(|_| ArtifactError::Manifest("manifest is not utf-8".into()))?;
        let doc = Json::parse(manifest_json)
            .map_err(|e| ArtifactError::Manifest(format!("manifest json: {e}")))?;
        let manifest = ArtifactManifest::from_json(&doc)?;

        let payload = &bytes[payload_off..];
        if payload.len() != manifest.payload_len {
            return Err(ArtifactError::Truncated);
        }
        let got = fnv64(payload);
        if got != manifest.payload_fnv {
            return Err(ArtifactError::Corrupt {
                what: "payload".into(),
                expected: manifest.payload_fnv,
                got,
            });
        }
        let params = ParamStore::read_from(&mut std::io::Cursor::new(payload))
            .map_err(|e| ArtifactError::PayloadMismatch(format!("payload decode: {e}")))?;
        let art = Artifact { manifest, params };
        art.verify()?;
        Ok(art)
    }

    /// Re-check the decoded parameters against the manifest: tensor
    /// arity, names, shapes, dtypes, and per-tensor checksums. `open`
    /// runs this; it is public so tests (and paranoid callers) can
    /// re-verify an artifact held in memory.
    pub fn verify(&self) -> Result<(), ArtifactError> {
        if self.manifest.tensors.len() != self.params.len() {
            return Err(ArtifactError::PayloadMismatch(format!(
                "manifest lists {} tensors, payload holds {}",
                self.manifest.tensors.len(),
                self.params.len()
            )));
        }
        for (entry, (name, t)) in self
            .manifest
            .tensors
            .iter()
            .zip(self.params.names.iter().zip(&self.params.tensors))
        {
            if &entry.name != name {
                return Err(ArtifactError::PayloadMismatch(format!(
                    "tensor order: manifest '{}' vs payload '{name}'",
                    entry.name
                )));
            }
            if entry.shape != t.shape() || entry.dtype != t.dtype() {
                return Err(ArtifactError::PayloadMismatch(format!(
                    "tensor '{name}': manifest {:?} {:?} vs payload {:?} {:?}",
                    entry.dtype,
                    entry.shape,
                    t.dtype(),
                    t.shape()
                )));
            }
            let got = tensor_fnv64(t);
            if got != entry.fnv64 {
                return Err(ArtifactError::Corrupt {
                    what: format!("tensor '{name}'"),
                    expected: entry.fnv64,
                    got,
                });
            }
        }
        Ok(())
    }

    /// Whether a byte buffer looks like an artifact file image (used by
    /// the HTTP front door to sniff inline uploads from JSON bodies).
    pub fn sniff(bytes: &[u8]) -> bool {
        bytes.len() >= 8 && &bytes[..8] == ARTIFACT_MAGIC
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hrr::model::init_native_params;
    use crate::hrr::Arch;

    fn tiny_cfg() -> HrrConfig {
        HrrConfig {
            arch: Arch::Hrrformer,
            task: "test".into(),
            vocab: 9,
            seq_len: 6,
            batch: 2,
            embed: 8,
            mlp_dim: 10,
            heads: 2,
            layers: 1,
            classes: 3,
            learned_pos: true,
        }
    }

    fn prov() -> Provenance {
        Provenance {
            task: "test".into(),
            base: "test_tiny".into(),
            step: 7,
            final_eval: Some((0.5, 0.875)),
        }
    }

    #[test]
    fn fnv64_matches_reference_vectors() {
        // Published FNV-1a 64 test vectors.
        assert_eq!(fnv64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn roundtrip_preserves_params_and_provenance() {
        let cfg = tiny_cfg();
        let params = init_native_params(&cfg, 42);
        let dir = std::env::temp_dir().join("hrrformer_artifact_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.hrrart");
        let written = Artifact::write(&path, &cfg, &params, prov()).unwrap();
        let art = Artifact::open(&path).unwrap();
        assert_eq!(art.manifest, written);
        assert_eq!(art.manifest.schema_version, SCHEMA_VERSION);
        assert_eq!(art.manifest.provenance, prov());
        assert_eq!(art.params.names, params.names);
        assert_eq!(art.params.tensors, params.tensors);
        assert_eq!(art.manifest.config_hash, fnv64(canonical_config(&cfg).as_bytes()));
    }

    #[test]
    fn open_bytes_equals_open() {
        let cfg = tiny_cfg();
        let params = init_native_params(&cfg, 1);
        let (bytes, manifest) = Artifact::to_bytes(&cfg, &params, prov()).unwrap();
        let art = Artifact::open_bytes(&bytes).unwrap();
        assert_eq!(art.manifest, manifest);
        assert!(Artifact::sniff(&bytes));
        assert!(!Artifact::sniff(b"{\"path\": \"x\"}"));
    }

    #[test]
    fn arch_is_recorded_and_defaults_for_legacy_manifests() {
        let cfg = tiny_cfg();
        let params = init_native_params(&cfg, 1);
        let (bytes, manifest) = Artifact::to_bytes(&cfg, &params, prov()).unwrap();
        assert_eq!(manifest.arch, "hrrformer");
        // hrrformer hashes predate the arch token: the canonical line
        // must not grow one, or every existing hash would shift
        assert!(!manifest.config.contains("arch="));

        let hg = HrrConfig { arch: Arch::HgConv, ..tiny_cfg() };
        let hgp = init_native_params(&hg, 1);
        let (_, hgm) = Artifact::to_bytes(&hg, &hgp, prov()).unwrap();
        assert_eq!(hgm.arch, "hgconv");
        assert!(hgm.config.contains(" arch=hgconv"));
        assert_ne!(hgm.config_hash, manifest.config_hash);

        // a manifest without the arch key (written before the field
        // existed) parses as hrrformer
        let mlen = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
        let manifest_json = String::from_utf8(bytes[12..12 + mlen].to_vec()).unwrap();
        let legacy = manifest_json.replacen("\"arch\":\"hrrformer\",", "", 1);
        assert_ne!(legacy, manifest_json, "serialized manifest must carry the arch key");
        let mut doc = Vec::new();
        doc.extend_from_slice(ARTIFACT_MAGIC);
        doc.extend_from_slice(&(legacy.len() as u32).to_le_bytes());
        doc.extend_from_slice(legacy.as_bytes());
        doc.extend_from_slice(&bytes[12 + mlen..]);
        let art = Artifact::open_bytes(&doc).unwrap();
        assert_eq!(art.manifest.arch, "hrrformer");
    }

    #[test]
    fn corruption_anywhere_in_payload_is_typed() {
        let cfg = tiny_cfg();
        let params = init_native_params(&cfg, 3);
        let (mut bytes, _) = Artifact::to_bytes(&cfg, &params, prov()).unwrap();
        // flip one bit deep in the payload (a weight byte)
        let n = bytes.len();
        bytes[n - 5] ^= 0x40;
        let err = Artifact::open_bytes(&bytes).unwrap_err();
        let typed = err.downcast_ref::<ArtifactError>().expect("typed artifact error");
        assert!(
            matches!(typed, ArtifactError::Corrupt { .. }),
            "expected Corrupt, got {typed:?}"
        );
    }

    #[test]
    fn tampered_manifest_or_magic_is_rejected() {
        let cfg = tiny_cfg();
        let params = init_native_params(&cfg, 3);
        let (bytes, _) = Artifact::to_bytes(&cfg, &params, prov()).unwrap();

        let mut bad_magic = bytes.clone();
        bad_magic[0] = b'X';
        let err = Artifact::open_bytes(&bad_magic).unwrap_err();
        assert_eq!(err.downcast_ref::<ArtifactError>(), Some(&ArtifactError::BadMagic));

        let mut truncated = bytes.clone();
        truncated.truncate(bytes.len() - 9);
        let err = Artifact::open_bytes(&truncated).unwrap_err();
        assert_eq!(err.downcast_ref::<ArtifactError>(), Some(&ArtifactError::Truncated));

        // a schema bump from the future must be refused, not misread
        let manifest_len =
            u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
        let manifest =
            String::from_utf8(bytes[12..12 + manifest_len].to_vec()).unwrap();
        let future = manifest.replacen("\"schema_version\":1", "\"schema_version\":99", 1);
        assert_ne!(future, manifest);
        let mut doc = Vec::new();
        doc.extend_from_slice(ARTIFACT_MAGIC);
        doc.extend_from_slice(&(future.len() as u32).to_le_bytes());
        doc.extend_from_slice(future.as_bytes());
        doc.extend_from_slice(&bytes[12 + manifest_len..]);
        let err = Artifact::open_bytes(&doc).unwrap_err();
        assert!(matches!(
            err.downcast_ref::<ArtifactError>(),
            Some(ArtifactError::SchemaVersion { found: 99, .. })
        ));
    }
}
