//! Baseline ratchet + report emission for hrrlint.
//!
//! The baseline (`lint_baseline.json`) grandfathers pre-existing
//! findings keyed by `(file, rule, content-hash)` with a count — never
//! line numbers, so unrelated edits don't churn it. A finding not
//! covered by the baseline is *new* and fails the run; baseline entries
//! with no matching finding are reported *stale* so the file can be
//! re-ratcheted downward.
//!
//! JSON report emission is canonical (fixed key order, fixed escaping
//! via `util::json`) and must stay byte-identical to the Python
//! mirror's emitter in `python/analysis/hrrlint.py`.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::fs;
use std::io;
use std::path::Path;

use super::rules::{lint_source, Finding, RULES};
use crate::util::json::{write_json, Json};

pub const BASELINE_VERSION: u64 = 1;

/// `(file, rule, hash) -> grandfathered count`.
pub type Baseline = BTreeMap<(String, String, String), usize>;

fn baseline_key(f: &Finding) -> (String, String, String) {
    (f.file.clone(), f.rule.clone(), f.hash.clone())
}

// ---------------------------------------------------------------------------
// Tree walk
// ---------------------------------------------------------------------------

/// All `.rs` files under `root`, as sorted forward-slash relative paths.
pub fn discover(root: &Path) -> io::Result<Vec<String>> {
    let mut out = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        for entry in fs::read_dir(&dir)? {
            let entry = entry?;
            let path = entry.path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
                let rel = path
                    .strip_prefix(root)
                    .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e))?;
                let joined: Vec<String> =
                    rel.components().map(|c| c.as_os_str().to_string_lossy().into_owned()).collect();
                out.push(joined.join("/"));
            }
        }
    }
    out.sort();
    Ok(out)
}

/// Lint every `.rs` file under `root`. Findings come back sorted by
/// `(file, line, rule)` — the canonical report order.
pub fn lint_tree(root: &Path) -> io::Result<(Vec<Finding>, usize)> {
    let rels = discover(root)?;
    let mut findings = Vec::new();
    for rel in &rels {
        let src = fs::read_to_string(root.join(rel))?;
        findings.extend(lint_source(rel, &src));
    }
    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule.as_str()).cmp(&(b.file.as_str(), b.line, b.rule.as_str()))
    });
    Ok((findings, rels.len()))
}

// ---------------------------------------------------------------------------
// Baseline I/O
// ---------------------------------------------------------------------------

pub fn load_baseline(path: &Path) -> Result<Baseline, String> {
    let text = fs::read_to_string(path).map_err(|e| format!("read {}: {e}", path.display()))?;
    let doc = Json::parse(&text).map_err(|e| format!("parse {}: {e}", path.display()))?;
    if doc.get("version").and_then(|v| v.as_i64()) != Some(BASELINE_VERSION as i64) {
        return Err(format!("unsupported baseline version in {}", path.display()));
    }
    let mut entries: Baseline = BTreeMap::new();
    for e in doc.get("entries").and_then(|v| v.as_arr()).unwrap_or(&[]) {
        let file = e.get("file").and_then(|v| v.as_str()).unwrap_or_default().to_string();
        let rule = e.get("rule").and_then(|v| v.as_str()).unwrap_or_default().to_string();
        let hash = e.get("hash").and_then(|v| v.as_str()).unwrap_or_default().to_string();
        let count = e.get("count").and_then(|v| v.as_usize()).unwrap_or(0);
        *entries.entry((file, rule, hash)).or_insert(0) += count;
    }
    Ok(entries)
}

/// Mark each finding new/baselined against the ratchet. Findings are
/// already sorted; within a `(file, rule, hash)` group the first
/// `count` occurrences are grandfathered, the rest are new.
/// Returns `(new, baselined, stale)`.
pub fn apply_baseline(findings: &mut [Finding], baseline: &Baseline) -> (usize, usize, usize) {
    let mut used: BTreeMap<(String, String, String), usize> = BTreeMap::new();
    let mut new = 0usize;
    for f in findings.iter_mut() {
        let key = baseline_key(f);
        let have = baseline.get(&key).copied().unwrap_or(0);
        let seen = used.entry(key).or_insert(0);
        if *seen < have {
            f.new = false;
            *seen += 1;
        } else {
            f.new = true;
            new += 1;
        }
    }
    let baselined = findings.len() - new;
    let mut stale = 0usize;
    for (key, count) in baseline {
        stale += count - used.get(key).copied().unwrap_or(0);
    }
    (new, baselined, stale)
}

pub fn write_baseline(path: &Path, findings: &[Finding]) -> io::Result<()> {
    let mut counts: Baseline = BTreeMap::new();
    for f in findings {
        *counts.entry(baseline_key(f)).or_insert(0) += 1;
    }
    let body = if counts.is_empty() {
        format!("{{\n  \"entries\": [],\n  \"version\": {BASELINE_VERSION}\n}}\n")
    } else {
        let mut parts = Vec::new();
        for ((file, rule, hash), count) in &counts {
            parts.push(format!(
                "    {{\"count\": {count}, \"file\": {}, \"hash\": {}, \"rule\": {}}}",
                json_string(file),
                json_string(hash),
                json_string(rule)
            ));
        }
        format!(
            "{{\n  \"entries\": [\n{}\n  ],\n  \"version\": {BASELINE_VERSION}\n}}\n",
            parts.join(",\n")
        )
    };
    fs::write(path, body)
}

// ---------------------------------------------------------------------------
// Report emission
// ---------------------------------------------------------------------------

/// Canonical JSON string: `util::json`'s escaper, shared with the wire
/// path (and transcribed verbatim in the Python mirror).
pub fn json_string(s: &str) -> String {
    let mut out = String::new();
    write_json(&Json::Str(s.to_string()), &mut out);
    out
}

/// The machine-readable report: fixed, alphabetical key order so the
/// Rust and Python emitters agree byte-for-byte.
pub fn report_json(
    findings: &[Finding],
    file_count: usize,
    baseline_entries: usize,
    new: usize,
    baselined: usize,
    stale: usize,
) -> String {
    let mut parts = Vec::new();
    for f in findings {
        parts.push(format!(
            "{{\"file\": {}, \"hash\": {}, \"line\": {}, \"message\": {}, \"new\": {}, \"rule\": {}, \"snippet\": {}}}",
            json_string(&f.file),
            json_string(&f.hash),
            f.line,
            json_string(&f.message),
            if f.new { "true" } else { "false" },
            json_string(&f.rule),
            json_string(&f.snippet),
        ));
    }
    let mut out = String::new();
    let _ = write!(
        out,
        "{{\"baseline_entries\": {baseline_entries}, \"baselined\": {baselined}, \"files_scanned\": {file_count}, \"findings\": [{}], \"new\": {new}, \"rules\": {}, \"stale\": {stale}, \"version\": {BASELINE_VERSION}}}",
        parts.join(", "),
        RULES.len(),
    );
    out
}

/// The human-readable report: one block per *new* finding plus a
/// summary line (same shape as the Python mirror's text output).
pub fn report_text(
    findings: &[Finding],
    file_count: usize,
    new: usize,
    baselined: usize,
    stale: usize,
) -> String {
    let mut out = String::new();
    for f in findings {
        if !f.new {
            continue;
        }
        let _ = writeln!(out, "{}:{}: [{}] {}\n    {}", f.file, f.line, f.rule, f.message, f.snippet);
    }
    let _ = writeln!(
        out,
        "hrrlint: {new} new, {baselined} baselined, {stale} stale baseline entries, {file_count} files scanned"
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_findings() -> Vec<Finding> {
        lint_source("engine/x.rs", "fn a(v: Option<u32>) -> u32 { v.unwrap() + v.unwrap() }\n")
    }

    #[test]
    fn ratchet_counts_and_staleness() {
        let mut findings = two_findings();
        assert_eq!(findings.len(), 2);
        assert_eq!(findings[0].hash, findings[1].hash);
        let key = baseline_key(&findings[0]);

        let mut baseline = Baseline::new();
        baseline.insert(key.clone(), 1);
        assert_eq!(apply_baseline(&mut findings, &baseline), (1, 1, 0));

        baseline.insert(key.clone(), 2);
        assert_eq!(apply_baseline(&mut findings, &baseline), (0, 2, 0));

        baseline.insert(key, 3);
        assert_eq!(apply_baseline(&mut findings, &baseline), (0, 2, 1));
    }

    #[test]
    fn baseline_roundtrip() {
        let mut findings = two_findings();
        let dir = std::env::temp_dir().join(format!("hrrlint_bl_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("baseline.json");
        write_baseline(&path, &findings).unwrap();
        let loaded = load_baseline(&path).unwrap();
        assert_eq!(loaded.values().sum::<usize>(), findings.len());
        assert_eq!(apply_baseline(&mut findings, &loaded), (0, findings.len(), 0));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn empty_baseline_writes_canonical_form() {
        let dir = std::env::temp_dir().join(format!("hrrlint_ebl_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("baseline.json");
        write_baseline(&path, &[]).unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "{\n  \"entries\": [],\n  \"version\": 1\n}\n");
        assert!(load_baseline(&path).unwrap().is_empty());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
