//! Integration: manifest → compile → execute real AOT artifacts.
//! Requires `make artifacts` (core set); skips cleanly otherwise.

mod common;

use hrrformer::model::{ParamStore, PredictSession, Session, TrainSession};
use hrrformer::runtime::{Runtime, Tensor};
use hrrformer::util::rng::Rng;

fn runtime() -> Runtime {
    Runtime::cpu().expect("PJRT CPU client")
}

fn random_batch(rng: &mut Rng, b: usize, t: usize, vocab: usize) -> Tensor {
    let data: Vec<i32> = (0..b * t).map(|_| rng.range(1, vocab as i64) as i32).collect();
    Tensor::i32(vec![b, t], data)
}

#[test]
fn manifest_loads_core_set() {
    let Some(m) = common::manifest_or_skip("manifest_loads_core_set") else { return };
    assert!(m.programs.len() >= 10, "expected core program set, got {}", m.programs.len());
    let spec = m.get("listops_hrrformer_small_T512_B8_train_step").unwrap();
    assert_eq!(spec.seq_len, 512);
    assert_eq!(spec.batch, 8);
    assert!(spec.param_count() > 10);
    // inputs = 3*params + step + ids + labels
    assert_eq!(spec.inputs.len(), 3 * spec.param_count() + 3);
}

#[test]
fn init_is_deterministic_in_seed() {
    let Some(m) = common::manifest_or_skip("init_is_deterministic_in_seed") else { return };
    let rt = runtime();
    let spec = m.get("ember_hrrformer_small_T256_B8_init").unwrap();
    let init = rt.load(spec).unwrap();
    let a = init.run(&[Tensor::scalar_u32(7)]).unwrap();
    let b = init.run(&[Tensor::scalar_u32(7)]).unwrap();
    let c = init.run(&[Tensor::scalar_u32(8)]).unwrap();
    assert_eq!(a.len(), spec.params.len());
    assert_eq!(a, b, "same seed must give identical params");
    assert_ne!(a, c, "different seed must give different params");
    // embedding table shape matches manifest
    let emb = ParamStore::from_tensors(&spec.params, a).unwrap();
    let table = emb.get("embed.table").expect("embed.table param");
    assert_eq!(table.shape(), &[257, 64]);
}

#[test]
fn predict_shapes_and_finiteness() {
    let Some(m) = common::manifest_or_skip("predict_shapes_and_finiteness") else { return };
    let rt = runtime();
    let sess = PredictSession::create(&rt, &m, "ember_hrrformer_small_T256_B8", 3).unwrap();
    // the Session trait surfaces the compiled bucket shape
    assert_eq!(sess.seq_len(), 256);
    assert_eq!(sess.batch(), 8);
    assert!(sess.param_scalars() > 0);
    let mut rng = Rng::new(0);
    let ids = random_batch(&mut rng, 8, 256, 257);
    let logits = sess.predict(&ids).unwrap();
    assert_eq!(logits.shape(), &[8, 2]);
    assert!(logits.as_f32().unwrap().iter().all(|v| v.is_finite()));
}

#[test]
fn train_step_updates_params_and_reduces_loss_on_fixed_batch() {
    let Some(m) =
        common::manifest_or_skip("train_step_updates_params_and_reduces_loss_on_fixed_batch")
    else {
        return;
    };
    let rt = runtime();
    let mut sess = TrainSession::create(&rt, &m, "ember_hrrformer_small_T1024_B8", 1).unwrap();
    let mut rng = Rng::new(42);
    let ids = random_batch(&mut rng, 8, 1024, 257);
    let labels = Tensor::i32(vec![8], (0..8).map(|i| (i % 2) as i32).collect());
    let before = sess.params.tensors[0].clone();
    let s0 = sess.train_step(&ids, &labels).unwrap();
    assert!(s0.loss.is_finite());
    assert_ne!(&before, &sess.params.tensors[0], "params must change");
    // overfit a single fixed batch: loss after N steps must drop
    let mut last = s0.loss;
    for _ in 0..8 {
        last = sess.train_step(&ids, &labels).unwrap().loss;
    }
    assert!(
        last < s0.loss,
        "loss should fall when overfitting one batch: {} -> {}",
        s0.loss,
        last
    );
}

#[test]
fn eval_step_is_pure() {
    let Some(m) = common::manifest_or_skip("eval_step_is_pure") else { return };
    let rt = runtime();
    let sess = TrainSession::create(&rt, &m, "ember_hrrformer_small_T1024_B8", 2).unwrap();
    let mut rng = Rng::new(9);
    let ids = random_batch(&mut rng, 8, 1024, 257);
    let labels = Tensor::i32(vec![8], vec![0, 1, 0, 1, 0, 1, 0, 1]);
    let a = sess.eval_step(&ids, &labels).unwrap();
    let b = sess.eval_step(&ids, &labels).unwrap();
    assert_eq!(a.loss, b.loss);
    assert_eq!(a.acc, b.acc);
    assert!((0.0..=1.0).contains(&a.acc));
}

#[test]
fn checkpoint_roundtrip_through_session() {
    let Some(m) = common::manifest_or_skip("checkpoint_roundtrip_through_session") else { return };
    let rt = runtime();
    let mut sess = TrainSession::create(&rt, &m, "ember_hrrformer_small_T1024_B8", 5).unwrap();
    let mut rng = Rng::new(1);
    let ids = random_batch(&mut rng, 8, 1024, 257);
    let labels = Tensor::i32(vec![8], vec![1; 8]);
    sess.train_step(&ids, &labels).unwrap();
    let dir = std::env::temp_dir().join("hrrformer_it");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("sess.ckpt");
    sess.save(&path).unwrap();

    let mut sess2 = TrainSession::create(&rt, &m, "ember_hrrformer_small_T1024_B8", 999).unwrap();
    sess2.restore(&path).unwrap();
    let e1 = sess.eval_step(&ids, &labels).unwrap();
    let e2 = sess2.eval_step(&ids, &labels).unwrap();
    assert_eq!(e1.loss, e2.loss, "restored params must reproduce eval loss");
}

#[test]
fn kernel_microbench_program_runs_with_reweighting_semantics() {
    let Some(m) =
        common::manifest_or_skip("kernel_microbench_program_runs_with_reweighting_semantics")
    else {
        return;
    };
    let rt = runtime();
    let spec = m.get("kernel_hrr_N4_T1024_H64").unwrap();
    let prog = rt.load(spec).unwrap();
    let mut rng = Rng::new(3);
    let mut mk = |rng: &mut Rng| {
        let data: Vec<f32> = (0..4 * 1024 * 64).map(|_| rng.normal() as f32 * 0.125).collect();
        Tensor::f32(vec![1, 4, 1024, 64], data)
    };
    let (q, k, v) = (mk(&mut rng), mk(&mut rng), mk(&mut rng));
    let out = prog.run(&[q, k, v.clone()]).unwrap();
    assert_eq!(out[0].shape(), &[1, 4, 1024, 64]);
    let o = out[0].as_f32().unwrap();
    assert!(o.iter().all(|x| x.is_finite()));
    // Eq.4: output rows are w_t * v_t with softmax weights in (0,1) —
    // each output row must be a positive scaling of v's row.
    let vv = v.as_f32().unwrap();
    let row = 64;
    for t in [0usize, 17, 511, 1023] {
        let a = &o[t * row..(t + 1) * row];
        let b = &vv[t * row..(t + 1) * row];
        let dot: f32 = a.iter().zip(b).map(|(x, y)| x * y).sum();
        let na: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
        let nb: f32 = b.iter().map(|x| x * x).sum::<f32>().sqrt();
        let cos = dot / (na * nb + 1e-9);
        assert!(cos > 0.99, "row {t} not collinear with v (cos={cos})");
    }
}
