//! Benchmark harness: one module per table/figure in the paper's
//! evaluation (DESIGN.md §4 experiment index). Each module exposes a
//! `run(...)` that prints the paper-style table and writes CSV next to
//! `results/`.

pub mod ember;
pub mod http;
pub mod inference;
pub mod lra;
pub mod native;
pub mod speed;
pub mod stream;
pub mod weights;

use std::path::PathBuf;

/// Where bench CSV/Markdown output lands.
pub fn results_dir() -> PathBuf {
    let d = PathBuf::from(
        std::env::var("HRRFORMER_RESULTS").unwrap_or_else(|_| "results".to_string()),
    );
    let _ = std::fs::create_dir_all(&d);
    d
}

/// Known model list in the paper's Table 5 ordering.
pub const EMBER_MODELS: &[&str] = &[
    "transformer",
    "luna",
    "performer",
    "linformer",
    "fnet",
    "linear_transformer",
    "hrrformer",
];

pub const LRA_MODELS: &[&str] = &[
    "transformer",
    "local",
    "linear_transformer",
    "linformer",
    "performer",
    "fnet",
    "luna",
    "hrrformer",
];
