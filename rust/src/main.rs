//! `repro` — the Hrrformer coordinator CLI (leader entrypoint).
//!
//! Subcommands:
//!   train            train one exported model config
//!   serve            run the batched inference service on synthetic load
//!   bench ember      Table 5 / Fig 1 / Fig 4
//!   bench lra        Table 1 / Table 2 / Fig 8 (--curves)
//!   bench speed      Table 4 / Fig 6
//!   bench inference  Table 7 (add --sweep-batch for Table 6)
//!   bench native     native hot-path sweep (single vs multi thread)
//!   bench stream     chunked streaming forward at T=131072 (mmap-fed)
//!   bench http       closed-loop load test of the HTTP front door
//!   bench weights    Fig 5 / Fig 9
//!   data             dump dataset samples
//!   inspect          list manifest programs
//!
//! Run with `--help` for flags.

use anyhow::{bail, Context, Result};

use hrrformer::bench;
use hrrformer::coordinator::{self, BatchPolicy, TrainConfig};
use hrrformer::data::mmap::{write_corpus, MmapCorpus};
use hrrformer::data::{by_task, Split, Stream};
use hrrformer::engine::{Backend, Engine};
use hrrformer::hrr::{with_arch, Arch, HrrConfig};
use hrrformer::net::{HttpConfig, HttpServer};
use hrrformer::runtime::{default_manifest, Runtime};
use hrrformer::stream::StreamConfig;
use hrrformer::util::cli::Args;

const USAGE: &str = "\
repro — Hrrformer reproduction coordinator

USAGE:
  repro train --base <program base> [--backend artifact|native] [--steps N] [--seed S]
              [--arch hrrformer|hgconv] [--dropout P] [--keep-artifacts N]
              [--eval-every N] [--eval-batches N] [--curve path.csv] [--ckpt path]
              [--emit-artifact path]
  repro serve [--backend artifact|native] [--arch hrrformer|hgconv] [--bases a,b,c]
              [--requests N] [--max-batch B] [--max-wait-ms MS] [--queue-depth D]
              [--seed S] [--workers K]
  repro serve --stream [--stream-base BASE] [--requests N] [--chunk TOKENS]
              [--append-bytes N] [--seed S] [--workers K]
  repro serve --http [--addr HOST:PORT] [--http-secs S] [--http-drivers N]
              [--accept-backlog N] [--idle-secs S] [--stream-base BASE]
              [--backend artifact|native] [--bases a,b,c] [--max-batch B]
              [--max-wait-ms MS] [--queue-depth D] [--seed S] [--workers K]
  repro bench ember     [--steps N] [--models a,b] [--timeout-s S]
  repro bench lra       [--steps N] [--models a,b] [--tasks t1,t2] [--curves]
  repro bench lra --native [--steps N] [--tasks t1,t2] [--seq-len T] [--batch B]
                        [--seed S] [--out BENCH_lra.json]
  repro bench speed     [--steps N]
  repro bench inference [--examples N] [--sweep-batch | --engine]
                        [--backend artifact|native]
  repro bench native    [--examples N] [--workers K] [--seed S]
                        [--arch hrrformer|hgconv] [--out BENCH_native.json]
  repro bench stream    [--examples N] [--base BASE] [--chunks a,b,c]
                        [--seed S] [--out BENCH_native.json]
  repro bench http      [--addr HOST:PORT] [--clients N] [--requests N]
                        [--overload-clients N] [--req-len T] [--base BASE]
                        [--queue-depth D] [--seed S] [--out BENCH_native.json]
  repro bench weights   [--steps N] [--multi-layer]
  repro data --task <task> [--n N] [--seq-len T]
  repro inspect

serve runs the typed Engine API on synthetic load: one bucket per
--bases entry, a routing thread that picks the smallest bucket fitting
each request, and one executor thread per bucket — so buckets batch and
execute in parallel. Over-length requests are truncated to the largest
bucket and replies carry an explicit `truncated` flag. --seed must be a
u32 and seeds parameter init for every bucket. On the native backend
--workers caps the engine-wide worker pool all buckets share (0 =
every core): busy buckets split one fixed thread set instead of each
spawning per-batch workers.

--backend picks the implementation: `artifact` (default) executes the
AOT-compiled XLA programs on PJRT runtimes (xla handles are !Send) and
needs `make artifacts`; `native` is the pure-Rust path (rust/src/hrr) —
no artifacts required, works on a fresh checkout. On `train`, native
runs reverse-mode autodiff + Adam with the paper's LR decay through the
same train→eval→checkpoint loop (--eval-every 0 = final eval only);
gradients are bit-identical at any worker count. --dropout P (native
only) enables embedding/residual dropout inside train_step — eval and
predict are untouched and the masked trajectory is reproducible from
--seed. --emit-artifact (native only) writes a versioned weight
artifact — a manifest (config hash, architecture, per-tensor checksums,
training provenance) over the checkpoint payload — deployable into a
running serve --http via POST /admin/reload with zero downtime;
--keep-artifacts N prunes the artifact directory to the N newest
.hrrart files afterwards (the just-emitted file is never pruned).

--arch picks the native token mixer and rewrites the model token of
--base/--bases accordingly: `hrrformer` (the paper's multi-head HRR
attention) or `hgconv` (gated holographic global convolution). The two
architectures train, serve and hot-reload through the same engine and
HTTP surface; only hrrformer supports the streaming endpoints (hgconv
streams answer a typed 409). Artifacts record their architecture and
reloads reject a cross-architecture swap per bucket. bench lra --native
trains + evals BOTH architectures across the five LRA loaders and
writes the accuracy matrix to BENCH_lra.json.

bench native times that native hot path directly (plan-cached FFTs,
reusable workspaces) over the default EMBER bucket ladder under all
three row schedulers — sequential, legacy per-call scoped threads, and
the shared persistent worker pool — and writes the BENCH_native.json
trajectory file at the repo root. Needs no artifacts. --workers 0
(default) uses every available core (--threads is an accepted alias).

serve --http runs the network front door: a zero-dependency HTTP/1.1
server (non-blocking listener + --http-drivers connection threads) over
the same engine — POST /classify (per-request deadline_ms maps onto the
batcher's max_wait; QueueFull backpressure surfaces as 429), POST
/stream/{open,append,finish} (chunked bodies welcome; needs
--stream-base), POST /admin/reload (hot-swap weights from an
--emit-artifact file — path JSON or raw upload; replies then carry the
new model_version), GET /metrics and GET /healthz. The accept queue is
bounded (--accept-backlog; full ⇒ canned 503), keep-alive connections
idle past --idle-secs are reclaimed (408 when a request was partially
received), and shutdown drains accepted in-flight requests before the
engine stops. --http-secs 0 (default) serves until killed. bench http is the matching closed-loop
load client: a steady phase and an overload phase (shallow
--queue-depth in-process, so 429s actually happen), recording exact
client-side p50/p99 into BENCH_native.json under an \"http\" key;
--addr points it at an external serve --http instead.

serve --stream runs the streaming subsystem (native only): one stream
executor serving open/append/finish on the --stream-base bucket
(default ember_hrrformer_small_T131072_B1 — the paper's T=131072 EMBER
workload). Inputs are fed from a memory-mapped corpus in --append-bytes
pieces; the server folds them into O(H) carried state per stream —
no (B, T) tensor is ever materialized at streaming T. bench stream
sweeps chunk sizes over the same mmap-fed chunked forward and merges
throughput + per-stream resident state into BENCH_native.json under a
\"stream\" key.

Artifacts are read from ./artifacts (override: HRRFORMER_ARTIFACTS).
Bench outputs land in ./results (override: HRRFORMER_RESULTS).
";

fn main() {
    let args = Args::from_env();
    if args.positional.is_empty() || args.bool("help") {
        print!("{USAGE}");
        return;
    }
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.positional[0].as_str() {
        "train" => cmd_train(args),
        "serve" => cmd_serve(args),
        "bench" => cmd_bench(args),
        "data" => cmd_data(args),
        "inspect" => cmd_inspect(),
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let base = args.get("base").context("--base is required (see `repro inspect`)")?.to_string();
    let base = apply_arch(parse_arch(args)?, &base)?;
    let cfg = TrainConfig {
        base,
        seed: args.u64("seed", 0),
        steps: args.usize("steps", 200),
        eval_every: args.usize("eval-every", 50),
        eval_batches: args.usize("eval-batches", 8),
        curve_csv: args.get("curve").map(Into::into),
        ckpt: args.get("ckpt").map(Into::into),
        artifact: args.get("emit-artifact").map(Into::into),
        dropout: args.f64("dropout", 0.0),
        keep_artifacts: args.usize("keep-artifacts", 0),
        verbose: true,
    };
    let report = match parse_backend(args)? {
        // native: pure-Rust autodiff + Adam — no manifest, no PJRT
        Backend::Native => coordinator::train_native(&cfg)?,
        Backend::Artifact => {
            let rt = Runtime::cpu()?;
            let manifest = default_manifest()?;
            coordinator::train(&rt, &manifest, &cfg)?
        }
    };
    let last = report.curve.last().cloned().unwrap_or_default();
    println!(
        "final: train loss {:.4}, train acc {:.4}, test acc {:.4}, {:.1}s total \
         ({:.2} examples/s over {:.1}s of train steps, {} params)",
        last.train_loss,
        report.final_train_acc,
        report.final_test_acc,
        report.total_secs,
        report.examples_per_sec,
        report.train_secs,
        report.param_scalars
    );
    Ok(())
}

/// Parse `--arch` into the native architecture selector (None when the
/// flag is absent — bases keep whatever model token they already carry).
fn parse_arch(args: &Args) -> Result<Option<Arch>> {
    match args.get("arch") {
        None => Ok(None),
        Some(s) => match Arch::parse(s) {
            Some(a) => Ok(Some(a)),
            None => bail!(
                "--arch '{s}' is not a native architecture (expected one of: {})",
                Arch::all().map(|a| a.as_str()).join(", ")
            ),
        },
    }
}

/// Apply `--arch` to one program base: rewrite its model token, or pass
/// the base through untouched when the flag is absent.
fn apply_arch(arch: Option<Arch>, base: &str) -> Result<String> {
    match arch {
        Some(a) => with_arch(base, a),
        None => Ok(base.to_string()),
    }
}

/// Parse `--seed` as a real u32 exactly once — no silent `as u32` wrap —
/// and thread the one validated value through `EngineBuilder`.
fn parse_seed(args: &Args) -> Result<u32> {
    match args.get("seed") {
        None => Ok(0),
        Some(s) => s
            .parse::<u32>()
            .with_context(|| format!("--seed '{s}' must be a u32 (0..=4294967295)")),
    }
}

/// Parse `--backend` into the engine's typed selector.
fn parse_backend(args: &Args) -> Result<Backend> {
    args.str("backend", "artifact").parse::<Backend>().map_err(anyhow::Error::msg)
}

fn cmd_serve(args: &Args) -> Result<()> {
    if args.bool("stream") {
        return cmd_serve_stream(args);
    }
    if args.bool("http") {
        return cmd_serve_http(args);
    }
    let backend = parse_backend(args)?;
    let arch = parse_arch(args)?;
    let bases = args
        .list("bases", &hrrformer::engine::DEFAULT_EMBER_BUCKETS)
        .iter()
        .map(|b| apply_arch(arch, b))
        .collect::<Result<Vec<_>>>()?;
    let n_requests = args.usize("requests", 64);
    let seed = parse_seed(args)?;
    eprintln!("[serve] building {} buckets ({backend:?} backend)…", bases.len());
    let builder = Engine::builder()
        .buckets(bases)
        .policy(BatchPolicy {
            max_batch: args.usize("max-batch", 8),
            max_wait: std::time::Duration::from_millis(args.u64("max-wait-ms", 20)),
        })
        .queue_depth(args.usize("queue-depth", 128))
        .seed(seed)
        .backend(backend)
        .worker_budget(args.usize("workers", 0));
    let engine = match backend {
        Backend::Artifact => builder.build(&default_manifest()?)?,
        Backend::Native => builder.build_native()?,
    };

    // synthetic load: ember byte sequences with varied lengths
    let ds = by_task("ember", 1024).unwrap();
    let mut stream = Stream::new(ds.as_ref(), Split::Test, seed as u64);
    let mut correct = 0usize;
    let mut truncated = 0usize;
    eprintln!("[serve] sending {n_requests} requests…");
    let pending: Vec<_> = (0..n_requests)
        .map(|i| {
            let mut ex = stream.next_example();
            // vary request lengths to exercise the router
            let keep = 128 + (i * 97) % 900;
            ex.ids.truncate(keep);
            let ticket = engine.submit_wait(ex.ids)?;
            Ok((ex.label, ticket))
        })
        .collect::<Result<_>>()?;
    for (label, ticket) in pending {
        let reply = ticket.wait()?;
        correct += (reply.label as i32 == label) as usize;
        truncated += reply.truncated as usize;
    }
    let stats = engine.stats();
    println!(
        "served {n_requests} requests: {:.1} req/s, p50 {:.1} ms, p99 {:.1} ms, mean {:.1} ms, {truncated} truncated, accuracy {:.2} (untrained params)",
        stats.throughput.per_second(),
        stats.latency.percentile_ms(50.0),
        stats.latency.percentile_ms(99.0),
        stats.latency.mean_ms(),
        correct as f64 / n_requests as f64,
    );
    engine.stop();
    Ok(())
}

/// `serve --http`: stand up the engine and put the network front door
/// ([`hrrformer::net::HttpServer`]) in front of it. Add `--stream-base`
/// to also expose the PR 6 streaming surface over
/// `POST /stream/{open,append,finish}`.
fn cmd_serve_http(args: &Args) -> Result<()> {
    let backend = parse_backend(args)?;
    let arch = parse_arch(args)?;
    let bases = args
        .list("bases", &hrrformer::engine::DEFAULT_EMBER_BUCKETS)
        .iter()
        .map(|b| apply_arch(arch, b))
        .collect::<Result<Vec<_>>>()?;
    let seed = parse_seed(args)?;
    eprintln!("[serve] building {} buckets ({backend:?} backend)…", bases.len());
    let mut builder = Engine::builder()
        .buckets(bases)
        .policy(BatchPolicy {
            max_batch: args.usize("max-batch", 8),
            max_wait: std::time::Duration::from_millis(args.u64("max-wait-ms", 20)),
        })
        .queue_depth(args.usize("queue-depth", 128))
        .seed(seed)
        .backend(backend)
        .worker_budget(args.usize("workers", 0));
    if let Some(stream_base) = args.get("stream-base") {
        anyhow::ensure!(
            backend == Backend::Native,
            "--stream-base requires --backend native (artifact programs are fixed-shape)"
        );
        builder = builder.stream_bucket(stream_base);
    }
    let engine = match backend {
        Backend::Artifact => builder.build(&default_manifest()?)?,
        Backend::Native => builder.build_native()?,
    };

    let cfg = HttpConfig {
        addr: args.str("addr", "127.0.0.1:8080"),
        drivers: args.usize("http-drivers", 4),
        accept_backlog: args.usize("accept-backlog", 64),
        idle_timeout: std::time::Duration::from_secs(args.u64("idle-secs", 60).max(1)),
        ..HttpConfig::default()
    };
    let server = HttpServer::start(cfg, &engine)?;
    println!("[serve] http listening on {}", server.addr());

    let secs = args.u64("http-secs", 0);
    if secs == 0 {
        eprintln!("[serve] serving until killed (--http-secs N for a bounded run)");
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }
    std::thread::sleep(std::time::Duration::from_secs(secs));
    eprintln!("[serve] --http-secs elapsed; draining…");
    // drain order: front door first (in-flight requests still have
    // executors), then the engine
    server.stop();
    engine.stop();
    Ok(())
}

/// `serve --stream`: stand up the streaming bucket and classify
/// mmap-fed byte streams through the engine's
/// open/append/finish client surface — the paper's T ≥ 100k EMBER
/// workload with O(H) carried state per stream.
fn cmd_serve_stream(args: &Args) -> Result<()> {
    let backend = args.str("backend", "native").parse::<Backend>().map_err(anyhow::Error::msg)?;
    anyhow::ensure!(
        backend == Backend::Native,
        "serve --stream requires the native backend (artifact programs are fixed-shape)"
    );
    let base = args.str("stream-base", "ember_hrrformer_small_T131072_B1");
    let n = args.usize("requests", 2);
    let append_bytes = args.usize("append-bytes", 65536).max(1);
    let seed = parse_seed(args)?;
    let t = HrrConfig::from_base(&base)?.seq_len;

    // mmap-fed inputs: the corpus lives on disk; the client reads and
    // appends O(append_bytes) pieces, so no full T-length row is ever
    // held in memory on either side of the channel.
    let corpus_path = std::env::temp_dir().join(format!("hrrformer_serve_stream_T{t}.bin"));
    let ds = by_task("ember", t).unwrap();
    eprintln!("[serve] writing {n} × T={t} corpus → {}", corpus_path.display());
    write_corpus(&corpus_path, ds.as_ref(), Split::Test, seed as u64, n, t)?;
    let corpus = MmapCorpus::open(&corpus_path)?;

    let mut scfg = StreamConfig::new(std::env::temp_dir().join("hrrformer_streams"));
    scfg.chunk_cap = args.usize("chunk", scfg.chunk_cap).max(1);
    eprintln!(
        "[serve] building streaming bucket {base} ({}; chunk {} tokens)…",
        if corpus.is_mapped() { "corpus memory-mapped" } else { "corpus seek+read fallback" },
        scfg.chunk_cap
    );
    let engine = Engine::builder()
        .stream_bucket(&base)
        .stream_config(scfg)
        .seed(seed)
        .backend(Backend::Native)
        .worker_budget(args.usize("workers", 0))
        .build_native()?;

    let t0 = std::time::Instant::now();
    let mut correct = 0usize;
    let mut buf = vec![0u8; append_bytes];
    for r in 0..n {
        let id = engine.open_stream()?;
        let mut off = 0usize;
        loop {
            let got = corpus.read_row_chunk(r, off, &mut buf)?;
            if got == 0 {
                break;
            }
            engine.append_stream(id, &buf[..got])?;
            off += got;
        }
        let out = engine.finish_stream(id)?;
        correct += (out.label as i32 == corpus.label(r)?) as usize;
        println!(
            "stream {id}: label {} ({} tokens, {} B carried state, truncated={})",
            out.label, out.tokens, out.resident_bytes, out.truncated
        );
    }
    let secs = t0.elapsed().as_secs_f64().max(1e-9);
    println!(
        "served {n} streams at T={t}: {:.2} s total ({:.0} tokens/s), accuracy {:.2} \
         (untrained params), O(H) state per stream",
        secs,
        (n * t) as f64 / secs,
        correct as f64 / n.max(1) as f64,
    );
    engine.stop();
    let _ = std::fs::remove_file(&corpus_path);
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    let which = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .context("bench <ember|lra|speed|inference|native|stream|http|weights>")?;
    // The manifest and runtime are resolved per arm: the engine serving
    // bench manages its own per-executor runtimes (and on the native
    // backend needs no manifest at all).
    match which {
        "ember" => {
            let manifest = default_manifest()?;
            let mut cfg = bench::ember::EmberBenchCfg::default();
            cfg.steps = args.usize("steps", cfg.steps);
            cfg.seed = args.u64("seed", cfg.seed);
            cfg.timeout_s = args.f64("timeout-s", cfg.timeout_s);
            if args.get("models").is_some() {
                cfg.models = args.list("models", &[]);
            }
            bench::ember::run(&Runtime::cpu()?, &manifest, &cfg)?;
        }
        "lra" => {
            let mut cfg = bench::lra::LraBenchCfg::default();
            cfg.steps = args.usize("steps", cfg.steps);
            cfg.seed = args.u64("seed", cfg.seed);
            cfg.curves = args.bool("curves");
            if args.get("models").is_some() {
                cfg.models = args.list("models", &[]);
            }
            if args.get("tasks").is_some() {
                cfg.tasks = args.list("tasks", &[]);
            }
            // --native: pure-Rust train+eval across both architectures —
            // no manifest, so this must short-circuit before
            // default_manifest() can fail on a fresh checkout
            if args.bool("native") {
                cfg.native_seq_len = args.usize("seq-len", cfg.native_seq_len);
                cfg.native_batch = args.usize("batch", cfg.native_batch);
                if let Some(out) = args.get("out") {
                    cfg.out = out.into();
                }
                bench::lra::run_native(&cfg)?;
                return Ok(());
            }
            let manifest = default_manifest()?;
            bench::lra::run(&Runtime::cpu()?, &manifest, &cfg)?;
        }
        "speed" => {
            let manifest = default_manifest()?;
            let mut cfg = bench::speed::SpeedBenchCfg::default();
            cfg.steps = args.usize("steps", cfg.steps);
            cfg.seed = args.u64("seed", cfg.seed);
            bench::speed::run(&Runtime::cpu()?, &manifest, &cfg)?;
        }
        "inference" => {
            let mut cfg = bench::inference::InferBenchCfg::default();
            cfg.examples = args.usize("examples", cfg.examples);
            cfg.seed = args.u64("seed", cfg.seed);
            cfg.sweep_batch = args.bool("sweep-batch");
            cfg.engine = args.bool("engine");
            cfg.backend = parse_backend(args)?;
            if cfg.engine {
                // native serving needs no manifest; artifact serving does
                let manifest = match cfg.backend {
                    Backend::Artifact => Some(default_manifest()?),
                    Backend::Native => None,
                };
                bench::inference::run_engine_serve(manifest.as_ref(), &cfg)?;
            } else {
                anyhow::ensure!(
                    cfg.backend == Backend::Artifact,
                    "--backend native is only supported with --engine \
                     (raw-session tables time the compiled XLA programs)"
                );
                let manifest = default_manifest()?;
                bench::inference::run(&Runtime::cpu()?, &manifest, &cfg)?;
            }
        }
        "native" => {
            // pure-Rust hot path: no manifest, no runtime, no artifacts
            let mut cfg = bench::native::NativeBenchCfg::default();
            cfg.examples = args.usize("examples", cfg.examples);
            cfg.seed = args.u64("seed", cfg.seed);
            if let Some(arch) = parse_arch(args)? {
                cfg.arch = arch;
            }
            // --workers (the engine-wide pool vocabulary) wins; --threads
            // stays as the PR 3 alias
            cfg.threads = args.usize("threads", cfg.threads);
            cfg.threads = args.usize("workers", cfg.threads);
            if let Some(out) = args.get("out") {
                cfg.out = out.into();
            }
            bench::native::run(&cfg)?;
        }
        "stream" => {
            // chunked streaming forward over an mmap corpus: no
            // manifest, no artifacts
            let mut cfg = bench::stream::StreamBenchCfg::default();
            cfg.rows = args.usize("examples", cfg.rows);
            cfg.seed = args.u64("seed", cfg.seed);
            if let Some(base) = args.get("base") {
                cfg.base = base.to_string();
            }
            if args.get("chunks").is_some() {
                cfg.chunks = args
                    .list("chunks", &[])
                    .iter()
                    .map(|s| {
                        s.parse::<usize>()
                            .with_context(|| format!("--chunks entry '{s}' must be a usize"))
                    })
                    .collect::<Result<_>>()?;
            }
            if let Some(out) = args.get("out") {
                cfg.out = out.into();
            }
            bench::stream::run(&cfg)?;
        }
        "http" => {
            // closed-loop load test against the HTTP front door; with
            // no --addr it stands up its own engine + server in-process
            let mut cfg = bench::http::HttpBenchCfg::default();
            cfg.addr = args.get("addr").map(|s| s.to_string());
            cfg.steady.0 = args.usize("clients", cfg.steady.0);
            cfg.steady.1 = args.usize("requests", cfg.steady.1);
            cfg.overload.0 = args.usize("overload-clients", cfg.overload.0);
            cfg.overload.1 = args.usize("overload-requests", cfg.overload.1);
            cfg.req_len = args.usize("req-len", cfg.req_len);
            if let Some(base) = args.get("base") {
                cfg.base = base.to_string();
            }
            cfg.queue_depth = args.usize("queue-depth", cfg.queue_depth);
            cfg.seed = args.u64("seed", cfg.seed);
            if let Some(out) = args.get("out") {
                cfg.out = out.into();
            }
            bench::http::run(&cfg)?;
        }
        "weights" => {
            let manifest = default_manifest()?;
            let mut cfg = bench::weights::WeightsBenchCfg::default();
            cfg.steps = args.usize("steps", cfg.steps);
            cfg.seed = args.u64("seed", cfg.seed);
            cfg.single_layer = !args.bool("multi-layer");
            bench::weights::run(&Runtime::cpu()?, &manifest, &cfg)?;
        }
        other => bail!("unknown bench '{other}'"),
    }
    Ok(())
}

fn cmd_data(args: &Args) -> Result<()> {
    let task = args.get("task").context("--task required")?;
    let t = args.usize("seq-len", 512);
    let n = args.usize("n", 3);
    let ds = by_task(task, t).with_context(|| format!("unknown task {task}"))?;
    let mut stream = Stream::new(ds.as_ref(), Split::Train, args.u64("seed", 0));
    for i in 0..n {
        let ex = stream.next_example();
        let preview: String = ex
            .ids
            .iter()
            .take(64)
            .map(|&id| {
                let b = (id - 1).clamp(0, 255) as u8;
                if (32..127).contains(&b) { b as char } else { '·' }
            })
            .collect();
        println!("#{i} label={} len={} | {preview}", ex.label, ex.ids.len());
    }
    Ok(())
}

fn cmd_inspect() -> Result<()> {
    let manifest = default_manifest()?;
    println!("{} programs in {}", manifest.programs.len(), manifest.dir.display());
    for (key, p) in &manifest.programs {
        println!(
            "  {key:<55} {:>12}  T={:<6} B={:<3} in={} out={}",
            p.kind,
            p.seq_len,
            p.batch,
            p.inputs.len(),
            p.outputs.len()
        );
    }
    Ok(())
}
