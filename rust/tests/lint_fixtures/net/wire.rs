//! hrrlint fixture: narrow-cast-wire (plus panic-path, since `net/` is
//! serving-path scope) seeded violations. Never compiled.

pub fn decode(len: u64, payload: &[u8]) -> usize {
    // The rule is syntactic: any `as usize` / `as u32` in wire-facing
    // code must go through a checked conversion instead.
    let n = len as usize; // FIXTURE: narrow-cast-wire (as usize)
    let tag = payload[0] as u32; // FIXTURE: narrow-cast-wire (as u32)
    let wide = n as u64; // ok: `as u64` widening is not flagged
    n + tag as usize + wide as usize // FIXTURE: narrow-cast-wire x2
}

pub fn parse(v: Option<u8>) -> u8 {
    v.unwrap() // FIXTURE: panic-path (net/ is serving scope)
}

pub fn checked(len: u64) -> Option<usize> {
    usize::try_from(len).ok() // ok: the mandated conversion
}
