//! Discrete Fourier transforms for the HRR binding kernels.
//!
//! Everything the native backend needs reduces to small per-head
//! transforms (H' = embed/heads, typically 8..64), so the implementation
//! favours exactness and zero dependencies over large-N throughput:
//!
//! * power-of-two lengths run an iterative radix-2 Cooley-Tukey FFT
//!   (bit-reversal permutation + butterflies) — O(n log n);
//! * every other length falls back to the naive O(n²) DFT, which at
//!   these sizes is still microseconds and keeps the API total.
//!
//! Transforms are computed in `f64` (callers hold `f32` model buffers and
//! round once on the way out — see `ops.rs`), with numpy's conventions:
//! forward is unscaled `Σ x·exp(-2πi·kn/N)`, inverse carries the `1/N`,
//! and the real-input pair [`rfft`]/[`irfft`] keeps `n/2 + 1` bins with
//! Hermitian symmetry supplying the rest.
//!
//! These free functions derive the bit-reversal permutation and every
//! twiddle per call; hot paths use the bit-identical precomputed
//! [`super::plan::FftPlan`] instead and keep this module as the plain
//! reference the plans are property-tested against.

use std::f64::consts::PI;

/// In-place complex FFT over parallel `re`/`im` buffers. `inverse`
/// flips the twiddle sign and applies the 1/N scale (numpy convention).
pub fn fft(re: &mut [f64], im: &mut [f64], inverse: bool) {
    let n = re.len();
    assert_eq!(n, im.len(), "re/im length mismatch");
    if n <= 1 {
        return;
    }
    if n.is_power_of_two() {
        fft_pow2(re, im, inverse);
    } else {
        let (r, i) = dft_naive(re, im, inverse);
        re.copy_from_slice(&r);
        im.copy_from_slice(&i);
    }
    if inverse {
        let s = 1.0 / n as f64;
        for v in re.iter_mut() {
            *v *= s;
        }
        for v in im.iter_mut() {
            *v *= s;
        }
    }
}

/// Iterative radix-2 Cooley-Tukey; `n` must be a power of two. Twiddles
/// come straight from sin/cos per butterfly index — at these sizes the
/// trig cost is irrelevant and it avoids accumulated twiddle drift.
fn fft_pow2(re: &mut [f64], im: &mut [f64], inverse: bool) {
    let n = re.len();
    // bit-reversal permutation
    let mut j = 0usize;
    for i in 1..n {
        let mut bit = n >> 1;
        while j & bit != 0 {
            j ^= bit;
            bit >>= 1;
        }
        j |= bit;
        if i < j {
            re.swap(i, j);
            im.swap(i, j);
        }
    }
    let sign = if inverse { 1.0 } else { -1.0 };
    let mut len = 2usize;
    while len <= n {
        let base = sign * 2.0 * PI / len as f64;
        for start in (0..n).step_by(len) {
            for k in 0..len / 2 {
                let ang = base * k as f64;
                let (wi, wr) = ang.sin_cos();
                let a = start + k;
                let b = a + len / 2;
                let vr = re[b] * wr - im[b] * wi;
                let vi = re[b] * wi + im[b] * wr;
                re[b] = re[a] - vr;
                im[b] = im[a] - vi;
                re[a] += vr;
                im[a] += vi;
            }
        }
        len <<= 1;
    }
}

/// Naive O(n²) DFT for non-power-of-two lengths (unscaled).
fn dft_naive(re: &[f64], im: &[f64], inverse: bool) -> (Vec<f64>, Vec<f64>) {
    let n = re.len();
    let sign = if inverse { 1.0 } else { -1.0 };
    let base = sign * 2.0 * PI / n as f64;
    let mut or = vec![0.0; n];
    let mut oi = vec![0.0; n];
    for (k, (ork, oik)) in or.iter_mut().zip(oi.iter_mut()).enumerate() {
        let mut sr = 0.0;
        let mut si = 0.0;
        for t in 0..n {
            let ang = base * ((k * t) % n) as f64;
            let (wi, wr) = ang.sin_cos();
            sr += re[t] * wr - im[t] * wi;
            si += re[t] * wi + im[t] * wr;
        }
        *ork = sr;
        *oik = si;
    }
    (or, oi)
}

/// Number of rFFT bins for a real signal of length `n` (numpy: n/2 + 1).
pub fn num_bins(n: usize) -> usize {
    n / 2 + 1
}

/// Real-to-complex FFT: `x` (length n) → (re, im) of `n/2 + 1` bins.
pub fn rfft(x: &[f64]) -> (Vec<f64>, Vec<f64>) {
    let n = x.len();
    let mut re = x.to_vec();
    let mut im = vec![0.0; n];
    fft(&mut re, &mut im, false);
    let k = num_bins(n);
    re.truncate(k);
    im.truncate(k);
    (re, im)
}

/// Buffer-reusing inverse of [`rfft`]: expand the `n/2 + 1` bins into
/// the caller's length-`n` scratch buffers by Hermitian symmetry
/// (`X[n-k] = conj(X[k])`) and inverse-transform in place. The real
/// signal lands in `re[..n]`; `im` holds rounding noise.
pub fn irfft_inplace(br: &[f64], bi: &[f64], re: &mut [f64], im: &mut [f64]) {
    let n = re.len();
    let k = num_bins(n);
    assert_eq!(br.len(), k, "irfft expects n/2+1 bins for n={n}");
    assert_eq!(bi.len(), k, "irfft expects n/2+1 bins for n={n}");
    re[..k].copy_from_slice(br);
    im[..k].copy_from_slice(bi);
    for j in k..n {
        re[j] = br[n - j];
        im[j] = -bi[n - j];
    }
    fft(re, im, true);
}

/// Inverse of [`rfft`]: `n/2 + 1` bins → real signal of length `n`
/// (allocating convenience over [`irfft_inplace`]).
pub fn irfft(re: &[f64], im: &[f64], n: usize) -> Vec<f64> {
    let mut fr = vec![0.0; n];
    let mut fi = vec![0.0; n];
    irfft_inplace(re, im, &mut fr, &mut fi);
    fr
}

#[cfg(test)]
mod tests {
    use super::*;

    fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
    }

    #[test]
    fn forward_matches_naive_on_pow2() {
        let x: Vec<f64> = (0..16).map(|i| (i as f64 * 0.7).sin()).collect();
        let mut re = x.clone();
        let mut im = vec![0.0; 16];
        fft(&mut re, &mut im, false);
        let (nr, ni) = dft_naive(&x, &vec![0.0; 16], false);
        assert!(max_abs_diff(&re, &nr) < 1e-10);
        assert!(max_abs_diff(&im, &ni) < 1e-10);
    }

    #[test]
    fn roundtrip_pow2_and_odd() {
        for n in [1usize, 2, 4, 7, 8, 12, 16, 27, 64] {
            let x: Vec<f64> = (0..n).map(|i| ((i * 37 + 11) % 17) as f64 - 8.0).collect();
            let y: Vec<f64> = (0..n).map(|i| ((i * 53 + 3) % 13) as f64 - 6.0).collect();
            let mut re = x.clone();
            let mut im = y.clone();
            fft(&mut re, &mut im, false);
            fft(&mut re, &mut im, true);
            assert!(max_abs_diff(&re, &x) < 1e-9, "re roundtrip n={n}");
            assert!(max_abs_diff(&im, &y) < 1e-9, "im roundtrip n={n}");
        }
    }

    #[test]
    fn rfft_irfft_roundtrip() {
        for n in [1usize, 2, 5, 8, 10, 16, 33] {
            let x: Vec<f64> = (0..n).map(|i| (i as f64 * 1.3).cos() * 2.0 - 0.5).collect();
            let (re, im) = rfft(&x);
            assert_eq!(re.len(), num_bins(n));
            let back = irfft(&re, &im, n);
            assert!(max_abs_diff(&back, &x) < 1e-9, "rfft roundtrip n={n}");
        }
    }

    #[test]
    fn rfft_dc_and_parseval() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let (re, im) = rfft(&x);
        // DC bin is the plain sum; bin 0 and Nyquist are purely real.
        assert!((re[0] - 10.0).abs() < 1e-12);
        assert!(im[0].abs() < 1e-12);
        assert!(im[2].abs() < 1e-12);
        // full-spectrum Parseval: Σ|x|² = (1/n)·Σ|X|² over all n bins
        let full: f64 = re[0] * re[0]
            + 2.0 * (re[1] * re[1] + im[1] * im[1])
            + re[2] * re[2];
        let time: f64 = x.iter().map(|v| v * v).sum();
        assert!((full / 4.0 - time).abs() < 1e-9);
    }
}
