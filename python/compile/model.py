"""Layer 2: the encoder zoo — init / forward / train_step / predict.

Pure-JAX (no flax); parameters are nested dicts. These functions are the
bodies that ``aot.py`` lowers ONCE to HLO text; the rust coordinator then
executes them with Python never on the request path.

Architecture (paper §3 Fig 3): token embedding + positions → L pre-LN
encoder blocks (mixer + MLP, residuals) → masked mean-pool → two dense
layers with ReLU → logits. Mixers are pluggable (``models.MIXERS``);
``hrrformer`` is the paper's contribution, the rest are its baselines.

Optimizer: Adam with the paper's exponential LR decay (1e-3 → 1e-5,
``decay_rate`` per epoch).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers
from .configs import ModelConfig
from .models import MIXERS

PAD_ID = 0  # token 0 is PAD everywhere (datasets reserve it)


# ---------------------------------------------------------------------------
# Init / forward
# ---------------------------------------------------------------------------


def init_params(key, cfg: ModelConfig):
    mixer = MIXERS[cfg.model]
    k_embed, k_pos, k_blocks, k_head = jax.random.split(key, 4)
    block_keys = jax.random.split(k_blocks, cfg.layers)
    blocks = []
    for i in range(cfg.layers):
        km, kp = jax.random.split(block_keys[i])
        blocks.append(
            {
                "ln1": layers.layernorm_init(cfg.embed),
                "mixer": mixer.init(km, cfg),
                "ln2": layers.layernorm_init(cfg.embed),
                "mlp": layers.mlp_init(kp, cfg.embed, cfg.mlp_dim),
            }
        )
    kh1, kh2 = jax.random.split(k_head)
    params = {
        "embed": layers.embed_init(k_embed, cfg.vocab, cfg.embed),
        "blocks": blocks,
        "ln_f": layers.layernorm_init(cfg.embed),
        "head1": layers.dense_init(kh1, cfg.embed, cfg.mlp_dim),
        "head2": layers.dense_init(kh2, cfg.mlp_dim, cfg.classes),
    }
    params.update(layers.positions_init(k_pos, cfg))
    return params


def encode(params, cfg: ModelConfig, ids, *, rng=None, deterministic=True,
           collect_weights=False):
    """ids: (B, T) int32 → features (B, T, E); PAD positions masked.

    With ``collect_weights`` (hrrformer only) also returns the per-layer
    attention weight maps ``(L, B, h, T)``.
    """
    mixer = MIXERS[cfg.model]
    mask = (ids != PAD_ID).astype(jnp.float32)  # (B, T)
    x = layers.embed(params["embed"], ids)
    x = layers.positions_apply(params, cfg, x)
    weights = []
    for i, blk in enumerate(params["blocks"]):
        key_i = None if rng is None else jax.random.fold_in(rng, i)
        h = layers.layernorm(blk["ln1"], x)
        if collect_weights and cfg.model == "hrrformer":
            y, w = MIXERS["hrrformer"].apply_with_weights(blk["mixer"], cfg, h, mask)
            weights.append(w)
        else:
            y = mixer.apply(blk["mixer"], cfg, h, mask, rng=key_i,
                            deterministic=deterministic)
        y = layers.dropout(key_i, cfg.dropout, y, deterministic)
        x = x + y
        h = layers.layernorm(blk["ln2"], x)
        h = layers.mlp(blk["mlp"], h)
        h = layers.dropout(
            None if key_i is None else jax.random.fold_in(key_i, 1000),
            cfg.dropout, h, deterministic)
        x = x + h
    x = layers.layernorm(params["ln_f"], x)
    if collect_weights:
        return x, mask, jnp.stack(weights) if weights else jnp.zeros((0,))
    return x, mask


def logits_fn(params, cfg: ModelConfig, ids, *, rng=None, deterministic=True):
    x, mask = encode(params, cfg, ids, rng=rng, deterministic=deterministic)
    denom = jnp.maximum(jnp.sum(mask, axis=1, keepdims=True), 1.0)
    pooled = jnp.sum(x * mask[..., None], axis=1) / denom  # masked mean-pool
    h = jax.nn.relu(layers.dense(params["head1"], pooled))
    return layers.dense(params["head2"], h)


def attn_weights_fn(params, cfg: ModelConfig, ids):
    """Fig 5/9 program: per-layer, per-head softmax weight maps."""
    _, _, w = encode(params, cfg, ids, deterministic=True, collect_weights=True)
    return w  # (L, B, h, T)


# ---------------------------------------------------------------------------
# Loss / optimizer
# ---------------------------------------------------------------------------


def loss_fn(params, cfg, ids, labels, rng):
    logits = logits_fn(params, cfg, ids, rng=rng, deterministic=rng is None)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()
    acc = (jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32).mean()
    return nll, acc


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return zeros, jax.tree_util.tree_map(jnp.zeros_like, params)


def lr_schedule(cfg: ModelConfig, step):
    """Paper: exponential decay per epoch from lr to lr_min."""
    epochs = step.astype(jnp.float32) / cfg.steps_per_epoch
    return jnp.maximum(cfg.lr * cfg.decay_rate**epochs, cfg.lr_min)


def adam_update(cfg: ModelConfig, params, m, v, grads, step,
                b1=0.9, b2=0.999, eps=1e-8):
    lr = lr_schedule(cfg, step)
    t = step.astype(jnp.float32) + 1.0
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, m, grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, v, grads)
    mhat_scale = 1.0 / (1.0 - b1**t)
    vhat_scale = 1.0 / (1.0 - b2**t)
    params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params, m, v,
    )
    return params, m, v


def train_step(cfg: ModelConfig, params, m, v, step, ids, labels):
    """One SGD step; returns (params', m', v', loss, acc).

    Dropout is keyed deterministically off ``step`` so the exported HLO
    is a pure function — reproducible from rust.
    """
    rng = jax.random.fold_in(jax.random.PRNGKey(0), step)
    (loss, acc), grads = jax.value_and_grad(
        lambda p: loss_fn(p, cfg, ids, labels, rng), has_aux=True
    )(params)
    params, m, v = adam_update(cfg, params, m, v, grads, step)
    return params, m, v, loss, acc


def eval_step(cfg: ModelConfig, params, ids, labels):
    loss, acc = loss_fn(params, cfg, ids, labels, None)
    return loss, acc
