"""Golden-vector exporter for the native Rust backend.

Writes small JSON fixtures (config + f32 parameters + token ids +
float64 reference logits) that ``rust/tests/golden_native.rs`` replays
through the pure-Rust forward pass (``rust/src/hrr``) and checks within
1e-4, plus a short golden *train curve* (config + params + per-step
batches + reference losses from a hand-derived reverse-mode backward +
Adam) that ``rust/tests/golden_train.rs`` replays through the native
trainer (``rust/src/hrr/grad.rs``).

Deliberately **numpy-only**: it mirrors the JAX reference
(``model.py`` + ``models/hrrformer.py`` + ``kernels/ref.py``) operation
by operation — same LayerNorm eps, same stabilized exact inverse with
eps 1e-6, same cosine eps, same masked softmax, same tanh-GELU (the
``jax.nn.gelu`` default) — so fixtures regenerate on any machine, no
accelerator stack required. Parameters are drawn once, cast to float32
(the dtype the Rust side stores), then the forward pass runs in float64
from those f32 values, exactly like the Rust implementation's
f32-buffers/f64-accumulators split.

Parameter names/order follow the canonical layout of
``rust/src/hrr/common/mod.rs::param_specs``. Fixtures whose config
carries ``"arch": "hgconv"`` swap the three per-block mixer slots for
the gated holographic convolution (``rust/src/hrr/hgconv``) and run its
numpy mirror instead of HRR attention; everything else is shared.

Usage:  python -m compile.export_golden   (from python/)
   or:  python python/compile/export_golden.py   (from the repo root)
"""

from __future__ import annotations

import json
import os

import numpy as np

EPS = 1e-6
PAD_ID = 0
OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "rust", "tests", "fixtures")


# ---------------------------------------------------------------------------
# Reference forward pass (float64, numpy)
# ---------------------------------------------------------------------------


def layernorm(x, scale, bias):
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) / np.sqrt(var + 1e-6) * scale + bias


def gelu_tanh(x):
    c = np.sqrt(2.0 / np.pi)
    return 0.5 * x * (1.0 + np.tanh(c * (x + 0.044715 * x**3)))


def sinusoid_positions(t, d):
    pos = np.arange(t)[:, None].astype(np.float64)
    i = np.arange(d)[None, :]
    angle = pos / np.power(10000.0, (2 * (i // 2)) / d)
    return np.where(i % 2 == 0, np.sin(angle), np.cos(angle))


def hrr_attention(q, k, v, mask):
    """Paper Eqs. 1-4 for one head batch: q,k,v (B,h,T,H'), mask (B,T)."""
    m = mask[:, None, :, None]  # (B,1,T,1)
    kf = np.fft.rfft(k * m, axis=-1)
    vf = np.fft.rfft(v, axis=-1)
    beta = (kf * vf).sum(axis=-2, keepdims=True)  # (B,h,1,K) — Eq. 1
    qf = np.fft.rfft(q, axis=-1)
    inv = np.conj(qf) / (np.abs(qf) ** 2 + EPS)
    v_hat = np.fft.irfft(beta * inv, n=q.shape[-1], axis=-1)  # Eq. 2
    num = (v * v_hat).sum(axis=-1, keepdims=True)
    den = np.linalg.norm(v, axis=-1, keepdims=True) * np.linalg.norm(
        v_hat, axis=-1, keepdims=True
    )
    a = num / (den + EPS)  # (B,h,T,1) — Eq. 3
    a = a + (1.0 - m) * (-1e9)
    w = np.exp(a - a.max(axis=-2, keepdims=True))
    w = w / w.sum(axis=-2, keepdims=True)  # Eq. 4 cleanup
    return w * v


def filter_len(cfg):
    """HGConv learned-taps length (rust hgconv::filter_len)."""
    return min(cfg["seq_len"], 64)


def hgconv_mix(cfg, h, gate, conv, taps, mask):
    """HGConv token mixer (rust/src/hrr/hgconv/mod.rs mixer_forward):
    gated per-channel length-t circular convolution of the projected
    input with zero-padded learned taps; PAD rows zeroed on the way in
    (they feed nothing into any output position) and on the way out."""
    b, t, e = h.shape
    g_pre = h @ gate
    u = (h @ conv) * mask[..., None]
    # short rows truncate the learned kernel with them
    fl = min(filter_len(cfg), t)
    pad = np.zeros((t, e))
    pad[:fl] = taps[:fl]
    c = np.fft.irfft(
        np.fft.rfft(u, axis=1) * np.fft.rfft(pad, axis=0)[None, :, :], n=t, axis=1
    )
    return gelu_tanh(g_pre) * c * mask[..., None]


def check_circ_conv_against_direct_sum():
    """The FFT identity hgconv_mix leans on, pinned against the O(t²)
    direct sum before any fixture is written (mirrors the rust unit
    test hgconv::tests::circ_conv_matches_the_direct_sum)."""
    rng = np.random.default_rng(12345)
    for n in (4, 7, 12, 16):
        a = rng.standard_normal(n)
        b = rng.standard_normal(n)
        fast = np.fft.irfft(np.fft.rfft(a) * np.fft.rfft(b), n=n)
        direct = np.array(
            [sum(a[k] * b[(n + i - k) % n] for k in range(n)) for i in range(n)]
        )
        assert np.max(np.abs(fast - direct)) < 1e-9, "circular-conv FFT identity broke"


def split_heads(x, heads):
    b, t, e = x.shape
    return x.reshape(b, t, heads, e // heads).transpose(0, 2, 1, 3)


def merge_heads(x):
    b, h, t, hp = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, t, h * hp)


def forward(cfg, params, ids):
    p = {name: arr.astype(np.float64) for name, arr in params}
    b, t = ids.shape
    e, heads = cfg["embed"], cfg["heads"]
    mask = (ids != PAD_ID).astype(np.float64)

    x = p["embed.table"][np.clip(ids, 0, cfg["vocab"] - 1)]
    if cfg["pos"] == "learned":
        x = x + p["pos.table"][:t][None, :, :]
    else:
        x = x + sinusoid_positions(t, e)[None, :, :]

    for i in range(cfg["layers"]):
        n = f"blocks.{i}."
        h = layernorm(x, p[n + "ln1.scale"], p[n + "ln1.bias"])
        if cfg.get("arch") == "hgconv":
            mixed = hgconv_mix(
                cfg, h, p[n + "mixer.gate.kernel"], p[n + "mixer.conv.kernel"],
                p[n + "mixer.filter.taps"], mask,
            )
        else:
            q = split_heads(h @ p[n + "mixer.query.kernel"], heads)
            k = split_heads(h @ p[n + "mixer.key.kernel"], heads)
            v = split_heads(h @ p[n + "mixer.value.kernel"], heads)
            mixed = merge_heads(hrr_attention(q, k, v, mask))
        x = x + mixed @ p[n + "mixer.output.kernel"]
        h = layernorm(x, p[n + "ln2.scale"], p[n + "ln2.bias"])
        h = gelu_tanh(h @ p[n + "mlp.fc1.kernel"] + p[n + "mlp.fc1.bias"])
        x = x + h @ p[n + "mlp.fc2.kernel"] + p[n + "mlp.fc2.bias"]

    x = layernorm(x, p["ln_f.scale"], p["ln_f.bias"])
    denom = np.maximum(mask.sum(axis=1, keepdims=True), 1.0)
    pooled = (x * mask[..., None]).sum(axis=1) / denom
    h = np.maximum(pooled @ p["head1.kernel"] + p["head1.bias"], 0.0)
    return h @ p["head2.kernel"] + p["head2.bias"]


# ---------------------------------------------------------------------------
# Parameter generation (canonical rust layout, f32 values)
# ---------------------------------------------------------------------------


def make_params(cfg, rng):
    """Ordered [(name, f32 array)] matching rust param_specs()."""
    e, mlp = cfg["embed"], cfg["mlp_dim"]

    def glorot(shape):
        scale = np.sqrt(2.0 / (shape[0] + shape[-1]))
        return (rng.standard_normal(shape) * scale).astype(np.float32)

    def normal(shape, std):
        return (rng.standard_normal(shape) * std).astype(np.float32)

    out = [("embed.table", normal((cfg["vocab"], e), 1.0 / np.sqrt(e)))]
    if cfg["pos"] == "learned":
        out.append(("pos.table", normal((cfg["seq_len"], e), 0.02)))
    for i in range(cfg["layers"]):
        n = f"blocks.{i}."
        # non-unit scales / non-zero LN+bias params so the fixture
        # actually exercises those code paths
        out.append((n + "ln1.scale", normal((e,), 0.1) + 1.0))
        out.append((n + "ln1.bias", normal((e,), 0.05)))
        if cfg.get("arch") == "hgconv":
            out.append((n + "mixer.gate.kernel", glorot((e, e))))
            out.append((n + "mixer.conv.kernel", glorot((e, e))))
            # big enough that the convolution output actually moves the
            # gated mix (init-scale taps would make parity trivial)
            out.append((n + "mixer.filter.taps", normal((filter_len(cfg), e), 0.2)))
        else:
            out.append((n + "mixer.query.kernel", glorot((e, e))))
            out.append((n + "mixer.key.kernel", glorot((e, e))))
            out.append((n + "mixer.value.kernel", glorot((e, e))))
        out.append((n + "mixer.output.kernel", glorot((e, e))))
        out.append((n + "ln2.scale", normal((e,), 0.1) + 1.0))
        out.append((n + "ln2.bias", normal((e,), 0.05)))
        out.append((n + "mlp.fc1.kernel", glorot((e, mlp))))
        out.append((n + "mlp.fc1.bias", normal((mlp,), 0.05)))
        out.append((n + "mlp.fc2.kernel", glorot((mlp, e))))
        out.append((n + "mlp.fc2.bias", normal((e,), 0.05)))
    out.append(("ln_f.scale", normal((e,), 0.1) + 1.0))
    out.append(("ln_f.bias", normal((e,), 0.05)))
    out.append(("head1.kernel", glorot((e, mlp))))
    out.append(("head1.bias", normal((mlp,), 0.05)))
    out.append(("head2.kernel", glorot((mlp, cfg["classes"]))))
    out.append(("head2.bias", normal((cfg["classes"],), 0.05)))
    return [(name, arr.astype(np.float32)) for name, arr in out]


# ---------------------------------------------------------------------------
# Reference backward pass + Adam (float64 math, float32 state)
#
# Hand-derived reverse-mode gradients of ``forward`` above, written
# per-row/per-head exactly like ``rust/src/hrr/grad.rs`` computes them
# and validated against central differences (see the self-check in
# ``export_train``). The optimizer is model.py's protocol verbatim:
# softmax-CE, Adam(b1=.9, b2=.999, eps=1e-8), exponential LR decay
# ``max(lr * decay_rate**(step/steps_per_epoch), lr_min)``. Parameters
# and both moments are *stored* float32 and every step computes in
# float64 from those f32 values — the same split the Rust trainer uses.
# ---------------------------------------------------------------------------


def layernorm_bwd(x, scale, gy):
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    rstd = 1.0 / np.sqrt(var + 1e-6)
    xhat = (x - mu) * rstd
    gxhat = gy * scale
    gx = rstd * (gxhat - gxhat.mean(axis=-1, keepdims=True)
                 - xhat * (gxhat * xhat).mean(axis=-1, keepdims=True))
    return gx, (gy * xhat).sum(axis=0), gy.sum(axis=0)


def gelu_tanh_bwd(x, gy):
    c = np.sqrt(2.0 / np.pi)
    th = np.tanh(c * (x + 0.044715 * x ** 3))
    dy = 0.5 * (1.0 + th) + 0.5 * x * (1.0 - th * th) * c * (1.0 + 3 * 0.044715 * x ** 2)
    return gy * dy


def _cbin(n, j):
    """Hermitian multiplicity of rfft bin j for a length-n real signal."""
    return 1.0 if (j == 0 or (n % 2 == 0 and j == n // 2)) else 2.0


def adjoint_irfft(g, n):
    """Adjoint of ``v = irfft(U, n)`` as a map R^{2k} -> R^n."""
    c = np.array([_cbin(n, j) for j in range(n // 2 + 1)])
    return np.fft.rfft(g) * (c / n)


def adjoint_rfft(gU, n):
    """Adjoint of ``U = rfft(x)`` (bins counted once each)."""
    c = np.array([_cbin(n, j) for j in range(n // 2 + 1)])
    return n * np.fft.irfft(gU / c, n)


def forward_row_tape(cfg, p, ids):
    """Forward one row, keeping every intermediate backward needs."""
    t = len(ids)
    e, heads = cfg["embed"], cfg["heads"]
    hd = e // heads
    mask = ids != PAD_ID
    x = p["embed.table"][np.clip(ids, 0, cfg["vocab"] - 1)].copy()
    if cfg["pos"] == "learned":
        x = x + p["pos.table"][:t]
    else:
        x = x + sinusoid_positions(t, e)
    tape = {"mask": mask, "blocks": []}
    for b in range(cfg["layers"]):
        n = f"blocks.{b}."
        bt = {"x_in": x.copy()}
        h1 = layernorm(x, p[n + "ln1.scale"], p[n + "ln1.bias"])
        q, k, v = (h1 @ p[n + "mixer." + w + ".kernel"] for w in ("query", "key", "value"))
        attn = np.zeros((t, e))
        vhat_all = np.zeros((t, e))
        w_all = np.zeros((heads, t))
        betas = []
        for h in range(heads):
            off = h * hd
            beta = np.zeros(hd // 2 + 1, dtype=complex)
            for i in range(t):
                if mask[i]:
                    beta += np.fft.rfft(k[i, off:off + hd]) * np.fft.rfft(v[i, off:off + hd])
            scores = np.full(t, -np.inf)
            for i in range(t):
                if not mask[i]:
                    continue
                qf = np.fft.rfft(q[i, off:off + hd])
                inv = np.conj(qf) / (np.abs(qf) ** 2 + EPS)
                vhat = np.fft.irfft(beta * inv, hd)
                vhat_all[i, off:off + hd] = vhat
                vv = v[i, off:off + hd]
                nv, nh = np.sqrt(vv @ vv), np.sqrt(vhat @ vhat)
                scores[i] = (vv @ vhat) / (nv * nh + EPS)
            if mask.any():
                ex = np.where(mask, np.exp(np.where(mask, scores - scores[mask].max(), 0.0)), 0.0)
                w_all[h] = ex / ex.sum()
            for i in range(t):
                if mask[i]:
                    attn[i, off:off + hd] = w_all[h, i] * v[i, off:off + hd]
            betas.append(beta)
        bt.update(h1=h1, q=q, k=k, v=v, attn=attn, vhat=vhat_all, w=w_all, beta=betas)
        x = x + attn @ p[n + "mixer.output.kernel"]
        bt["x_mid"] = x.copy()
        h2 = layernorm(x, p[n + "ln2.scale"], p[n + "ln2.bias"])
        mlp_pre = h2 @ p[n + "mlp.fc1.kernel"] + p[n + "mlp.fc1.bias"]
        x = x + gelu_tanh(mlp_pre) @ p[n + "mlp.fc2.kernel"] + p[n + "mlp.fc2.bias"]
        bt.update(h2=h2, mlp_pre=mlp_pre)
        tape["blocks"].append(bt)
    tape["x_final"] = x.copy()
    hf = layernorm(x, p["ln_f.scale"], p["ln_f.bias"])
    n_valid = max(int(mask.sum()), 1)
    pooled = hf[mask].sum(axis=0) / n_valid if mask.any() else np.zeros(e)
    head_pre = pooled @ p["head1.kernel"] + p["head1.bias"]
    logits = np.maximum(head_pre, 0.0) @ p["head2.kernel"] + p["head2.bias"]
    tape.update(n_valid=n_valid, pooled=pooled, head_pre=head_pre, logits=logits)
    return tape


def softmax_ce(logits, label):
    m = logits.max()
    z = np.exp(logits - m)
    nll = m + np.log(z.sum()) - logits[label]
    g = z / z.sum()
    g[label] -= 1.0
    return nll, g


def attention_bwd(cfg, bt, mask, head, g_attn, gq, gk, gv):
    """Backward through one head of HRR attention (Eqs. 1-4)."""
    t = g_attn.shape[0]
    hd = cfg["embed"] // cfg["heads"]
    off = head * hd
    w, beta = bt["w"][head], bt["beta"][head]
    q, k, v, vhat = bt["q"], bt["k"], bt["v"], bt["vhat"]
    # Eq. 4: out_i = w_i * v_i → gw, direct v term, then softmax backward
    gw = np.zeros(t)
    for i in range(t):
        if mask[i]:
            gw[i] = g_attn[i, off:off + hd] @ v[i, off:off + hd]
            gv[i, off:off + hd] += w[i] * g_attn[i, off:off + hd]
    S = float((w * gw)[mask].sum())
    gs = np.where(mask, w * (gw - S), 0.0)
    gbeta = np.zeros(hd // 2 + 1, dtype=complex)
    for i in range(t):
        if not mask[i]:
            continue
        # Eq. 3 cosine backward
        vv, vh = v[i, off:off + hd], vhat[i, off:off + hd]
        num = float(vv @ vh)
        a, b = np.sqrt(vv @ vv), np.sqrt(vh @ vh)
        den = a * b + EPS
        gnum = gs[i] / den
        gden = -gs[i] * num / (den * den)
        gv[i, off:off + hd] += gnum * vh + (gden * b / a * vv if a > 0 else 0.0)
        gvh = gnum * vv + (gden * a / b * vh if b > 0 else 0.0)
        # Eq. 2 backward: vhat = irfft(beta · conj(Qf)/(|Qf|²+ε))
        gU = adjoint_irfft(gvh, hd)
        qf = np.fft.rfft(q[i, off:off + hd])
        x, y = qf.real, qf.imag
        d2 = x * x + y * y + EPS
        gbeta += gU * np.conj((x - 1j * y) / d2)
        dinv_dx = (d2 - 2 * x * x + 2j * x * y) / (d2 * d2)
        dinv_dy = (-2 * x * y + 1j * (2 * y * y - d2)) / (d2 * d2)
        gqf_r = gU.real * (beta * dinv_dx).real + gU.imag * (beta * dinv_dx).imag
        gqf_i = gU.real * (beta * dinv_dy).real + gU.imag * (beta * dinv_dy).imag
        gq[i, off:off + hd] += adjoint_rfft(gqf_r + 1j * gqf_i, hd)
    # Eq. 1 backward: beta = Σ Kf_i · Vf_i over unmasked positions
    for i in range(t):
        if mask[i]:
            kf = np.fft.rfft(k[i, off:off + hd])
            vf = np.fft.rfft(v[i, off:off + hd])
            gk[i, off:off + hd] += adjoint_rfft(gbeta * np.conj(vf), hd)
            gv[i, off:off + hd] += adjoint_rfft(gbeta * np.conj(kf), hd)


def backward_row(cfg, p, ids, tape, g_logits):
    t = len(ids)
    e, heads = cfg["embed"], cfg["heads"]
    mask = tape["mask"]
    grads = {name: np.zeros_like(arr) for name, arr in p.items()}
    head_act = np.maximum(tape["head_pre"], 0.0)
    grads["head2.bias"] += g_logits
    grads["head2.kernel"] += np.outer(head_act, g_logits)
    g_head_pre = (p["head2.kernel"] @ g_logits) * (tape["head_pre"] > 0.0)
    grads["head1.bias"] += g_head_pre
    grads["head1.kernel"] += np.outer(tape["pooled"], g_head_pre)
    g_pooled = p["head1.kernel"] @ g_head_pre
    g_hf = np.where(mask[:, None], g_pooled[None, :] / tape["n_valid"], 0.0)
    gx, gs_, gb_ = layernorm_bwd(tape["x_final"], p["ln_f.scale"], g_hf)
    grads["ln_f.scale"] += gs_
    grads["ln_f.bias"] += gb_
    for b in reversed(range(cfg["layers"])):
        n = f"blocks.{b}."
        bt = tape["blocks"][b]
        mlp_act = gelu_tanh(bt["mlp_pre"])
        grads[n + "mlp.fc2.bias"] += gx.sum(axis=0)
        grads[n + "mlp.fc2.kernel"] += mlp_act.T @ gx
        g_mlp_pre = gelu_tanh_bwd(bt["mlp_pre"], gx @ p[n + "mlp.fc2.kernel"].T)
        grads[n + "mlp.fc1.bias"] += g_mlp_pre.sum(axis=0)
        grads[n + "mlp.fc1.kernel"] += bt["h2"].T @ g_mlp_pre
        gx2, gs_, gb_ = layernorm_bwd(bt["x_mid"], p[n + "ln2.scale"],
                                      g_mlp_pre @ p[n + "mlp.fc1.kernel"].T)
        grads[n + "ln2.scale"] += gs_
        grads[n + "ln2.bias"] += gb_
        gx = gx + gx2  # grad w.r.t. x_mid (residual + LN2 path)
        grads[n + "mixer.output.kernel"] += bt["attn"].T @ gx
        g_attn = gx @ p[n + "mixer.output.kernel"].T
        gq = np.zeros((t, e))
        gk = np.zeros((t, e))
        gv = np.zeros((t, e))
        for h in range(heads):
            attention_bwd(cfg, bt, mask, h, g_attn, gq, gk, gv)
        grads[n + "mixer.query.kernel"] += bt["h1"].T @ gq
        grads[n + "mixer.key.kernel"] += bt["h1"].T @ gk
        grads[n + "mixer.value.kernel"] += bt["h1"].T @ gv
        g_h1 = (gq @ p[n + "mixer.query.kernel"].T
                + gk @ p[n + "mixer.key.kernel"].T
                + gv @ p[n + "mixer.value.kernel"].T)
        gx1, gs_, gb_ = layernorm_bwd(bt["x_in"], p[n + "ln1.scale"], g_h1)
        grads[n + "ln1.scale"] += gs_
        grads[n + "ln1.bias"] += gb_
        gx = gx + gx1
    ids_c = np.clip(ids, 0, cfg["vocab"] - 1)
    for i in range(t):
        grads["embed.table"][ids_c[i]] += gx[i]
    if cfg["pos"] == "learned":
        grads["pos.table"][:t] += gx
    return grads


def loss_and_grads(cfg, params32, ids_batch, labels):
    """Mean softmax-CE loss/acc + mean gradients over a (B, T) batch."""
    p = {name: arr.astype(np.float64) for name, arr in params32}
    B = ids_batch.shape[0]
    total = {name: np.zeros_like(arr) for name, arr in p.items()}
    loss, correct = 0.0, 0
    for r in range(B):
        tape = forward_row_tape(cfg, p, ids_batch[r])
        nll, g_logits = softmax_ce(tape["logits"], labels[r])
        loss += nll
        correct += int(np.argmax(tape["logits"]) == labels[r])
        g = backward_row(cfg, p, ids_batch[r], tape, g_logits)
        for name in total:
            total[name] += g[name]
    return loss / B, correct / B, {n: g / B for n, g in total.items()}


def train_reference(cfg, hyper, params, batches):
    """Run the full training protocol; returns per-step (loss, acc)."""
    params = [(n, a.copy()) for n, a in params]
    m = {n: np.zeros_like(a, dtype=np.float32) for n, a in params}
    v = {n: np.zeros_like(a, dtype=np.float32) for n, a in params}
    curve = []
    for step, (ids, labels) in enumerate(batches):
        loss, acc, grads = loss_and_grads(cfg, params, ids, labels)
        curve.append((loss, acc))
        lr = max(hyper["lr"] * hyper["decay_rate"] ** (step / hyper["steps_per_epoch"]),
                 hyper["lr_min"])
        t = step + 1.0
        out = []
        for name, p32 in params:
            g = grads[name]
            m64 = 0.9 * m[name].astype(np.float64) + 0.1 * g
            v64 = 0.999 * v[name].astype(np.float64) + 0.001 * g * g
            mhat = m64 / (1.0 - 0.9 ** t)
            vhat = v64 / (1.0 - 0.999 ** t)
            p64 = p32.astype(np.float64) - lr * mhat / (np.sqrt(vhat) + 1e-8)
            m[name] = m64.astype(np.float32)
            v[name] = v64.astype(np.float32)
            out.append((name, p64.astype(np.float32)))
        params = out
    return curve, params


def export_train(name, cfg, hyper, seed, steps):
    rng = np.random.default_rng(seed)
    params = make_params(cfg, rng)
    b, t = cfg["batch"], cfg["seq_len"]

    # self-check: the hand-derived backward must match central
    # differences before we pin a fixture on it
    ids0 = rng.integers(1, cfg["vocab"], size=(b, t)).astype(np.int64)
    ids0[-1, -t // 3:] = PAD_ID
    labels0 = rng.integers(0, cfg["classes"], size=b)
    _, _, grads = loss_and_grads(cfg, params, ids0, labels0)
    h = 1e-5
    for pname, arr32 in params:
        flat32 = arr32.reshape(-1)
        gflat = grads[pname].reshape(-1)
        for j in rng.choice(len(flat32), size=min(4, len(flat32)), replace=False):
            old = flat32[j]
            def loss_at(val):
                flat32[j] = val
                l, _, _ = loss_and_grads(cfg, params, ids0, labels0)
                return l
            # use the *realized* f32 perturbation as the divisor — the
            # state is float32, so old±h rounds
            plus = np.float32(old + h)
            minus = np.float32(old - h)
            num = (loss_at(plus) - loss_at(minus)) / (float(plus) - float(minus))
            flat32[j] = old
            err = abs(num - gflat[j]) / max(1e-8, abs(num), abs(gflat[j]))
            assert err < 1e-4 or (abs(num) < 1e-9 and abs(gflat[j]) < 1e-9), (
                f"backward self-check failed at {pname}[{j}]: "
                f"analytic {gflat[j]:.8g} vs numeric {num:.8g}")

    # two alternating fixed batches: learnable (the trainer overfits
    # them), so the reference curve also pins that loss *decreases*
    pool = []
    for _ in range(2):
        ids = rng.integers(1, cfg["vocab"], size=(b, t)).astype(np.int64)
        ids[-1, t - t // 4:] = PAD_ID  # keep the mask in play every step
        labels = rng.integers(0, cfg["classes"], size=b)
        pool.append((ids, labels))
    batches = [pool[s % len(pool)] for s in range(steps)]
    # reference *gradients* at step 0, so the rust side can pin its
    # analytic backward per parameter tensor (not just through losses)
    _, _, grads0 = loss_and_grads(cfg, params, batches[0][0], batches[0][1])
    curve, _ = train_reference(cfg, hyper, params, batches)
    assert curve[-1][0] < curve[0][0], "reference train curve must decrease"

    doc = {
        "name": name,
        "seed": seed,
        "config": cfg,
        "hyper": hyper,
        "params": [
            {"name": pname, "shape": list(arr.shape),
             "data": [float(x) for x in arr.reshape(-1)]}
            for pname, arr in params
        ],
        "steps": [
            {
                "ids": ids.tolist(),
                "labels": [int(l) for l in labels],
                "loss": float(curve[s][0]),
                "acc": float(curve[s][1]),
            }
            for s, (ids, labels) in enumerate(batches)
        ],
        "step0_grads": [
            {"name": pname, "data": [float(x) for x in grads0[pname].reshape(-1)]}
            for pname, _ in params
        ],
        "tolerance": 5e-3,
    }
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(doc, f)
    print(f"wrote {path}: {steps} train steps, loss {curve[0][0]:.4f} -> {curve[-1][0]:.4f}")


def export(name, cfg, seed, row_t=None):
    rng = np.random.default_rng(seed)
    params = make_params(cfg, rng)
    # row_t < seq_len pins the short-row path (the native forward
    # accepts any t ≤ the bucket length; hgconv truncates its taps)
    b, t = cfg["batch"], row_t or cfg["seq_len"]
    ids = rng.integers(1, cfg["vocab"], size=(b, t)).astype(np.int32)
    # trailing PAD on the last row exercises the mask everywhere
    ids[-1, t - t // 3 :] = PAD_ID
    logits = forward(cfg, params, ids)

    doc = {
        "name": name,
        "seed": seed,
        "config": cfg,
        "ids": ids.tolist(),
        "params": [
            {
                "name": pname,
                "shape": list(arr.shape),
                "data": [float(v) for v in arr.reshape(-1)],
            }
            for pname, arr in params
        ],
        "logits": [[float(v) for v in row] for row in logits],
        "tolerance": 1e-4,
    }
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(doc, f)
    print(f"wrote {path}: B={b} T={t} E={cfg['embed']} heads={cfg['heads']} "
          f"layers={cfg['layers']} -> logits {np.asarray(logits).shape}")


def main():
    # power-of-two head dim (radix-2 FFT path), fixed sinusoid positions
    export(
        "golden_hrr_fixed",
        {
            "task": "golden",
            "vocab": 11,
            "seq_len": 12,
            "batch": 2,
            "embed": 16,
            "mlp_dim": 32,
            "heads": 2,
            "layers": 2,
            "classes": 4,
            "pos": "fixed",
        },
        seed=20230701,
    )
    # non-power-of-two head dim (naive-DFT fallback), learned positions
    export(
        "golden_hrr_learned",
        {
            "task": "golden",
            "vocab": 9,
            "seq_len": 10,
            "batch": 2,
            "embed": 12,
            "mlp_dim": 16,
            "heads": 2,
            "layers": 1,
            "classes": 3,
            "pos": "learned",
        },
        seed=777,
    )
    # second architecture: gated holographic global convolution, full
    # taps (t == filter_len), fixed positions, PAD in play
    check_circ_conv_against_direct_sum()
    export(
        "golden_hgconv",
        {
            "task": "golden",
            "arch": "hgconv",
            "vocab": 13,
            "seq_len": 12,
            "batch": 2,
            "embed": 16,
            "mlp_dim": 32,
            "heads": 2,
            "layers": 2,
            "classes": 4,
            "pos": "fixed",
        },
        seed=20240811,
    )
    # hgconv short rows: t=6 < filter_len=10, so the learned taps are
    # truncated with the row; learned positions sliced to a prefix
    export(
        "golden_hgconv_short",
        {
            "task": "golden",
            "arch": "hgconv",
            "vocab": 9,
            "seq_len": 10,
            "batch": 2,
            "embed": 12,
            "mlp_dim": 16,
            "heads": 2,
            "layers": 1,
            "classes": 3,
            "pos": "learned",
        },
        seed=424242,
        row_t=6,
    )
    # short golden train curve: pow2 head dim, learned positions (the
    # pos-table gradient path), LR decay fast enough to move within the
    # fixture's steps
    export_train(
        "golden_hrr_train",
        {
            "task": "golden",
            "vocab": 11,
            "seq_len": 10,
            "batch": 2,
            "embed": 16,
            "mlp_dim": 24,
            "heads": 2,
            "layers": 2,
            "classes": 3,
            "pos": "learned",
        },
        {"lr": 1e-3, "lr_min": 1e-5, "decay_rate": 0.9, "steps_per_epoch": 4},
        seed=20230705,
        steps=12,
    )


if __name__ == "__main__":
    main()
