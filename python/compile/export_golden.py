"""Golden-vector exporter for the native Rust backend.

Writes small JSON fixtures (config + f32 parameters + token ids +
float64 reference logits) that ``rust/tests/golden_native.rs`` replays
through the pure-Rust forward pass (``rust/src/hrr``) and checks within
1e-4.

Deliberately **numpy-only**: it mirrors the JAX reference
(``model.py`` + ``models/hrrformer.py`` + ``kernels/ref.py``) operation
by operation — same LayerNorm eps, same stabilized exact inverse with
eps 1e-6, same cosine eps, same masked softmax, same tanh-GELU (the
``jax.nn.gelu`` default) — so fixtures regenerate on any machine, no
accelerator stack required. Parameters are drawn once, cast to float32
(the dtype the Rust side stores), then the forward pass runs in float64
from those f32 values, exactly like the Rust implementation's
f32-buffers/f64-accumulators split.

Parameter names/order follow the canonical layout of
``rust/src/hrr/model.rs::param_specs``.

Usage:  python -m compile.export_golden   (from python/)
   or:  python python/compile/export_golden.py   (from the repo root)
"""

from __future__ import annotations

import json
import os

import numpy as np

EPS = 1e-6
PAD_ID = 0
OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "rust", "tests", "fixtures")


# ---------------------------------------------------------------------------
# Reference forward pass (float64, numpy)
# ---------------------------------------------------------------------------


def layernorm(x, scale, bias):
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) / np.sqrt(var + 1e-6) * scale + bias


def gelu_tanh(x):
    c = np.sqrt(2.0 / np.pi)
    return 0.5 * x * (1.0 + np.tanh(c * (x + 0.044715 * x**3)))


def sinusoid_positions(t, d):
    pos = np.arange(t)[:, None].astype(np.float64)
    i = np.arange(d)[None, :]
    angle = pos / np.power(10000.0, (2 * (i // 2)) / d)
    return np.where(i % 2 == 0, np.sin(angle), np.cos(angle))


def hrr_attention(q, k, v, mask):
    """Paper Eqs. 1-4 for one head batch: q,k,v (B,h,T,H'), mask (B,T)."""
    m = mask[:, None, :, None]  # (B,1,T,1)
    kf = np.fft.rfft(k * m, axis=-1)
    vf = np.fft.rfft(v, axis=-1)
    beta = (kf * vf).sum(axis=-2, keepdims=True)  # (B,h,1,K) — Eq. 1
    qf = np.fft.rfft(q, axis=-1)
    inv = np.conj(qf) / (np.abs(qf) ** 2 + EPS)
    v_hat = np.fft.irfft(beta * inv, n=q.shape[-1], axis=-1)  # Eq. 2
    num = (v * v_hat).sum(axis=-1, keepdims=True)
    den = np.linalg.norm(v, axis=-1, keepdims=True) * np.linalg.norm(
        v_hat, axis=-1, keepdims=True
    )
    a = num / (den + EPS)  # (B,h,T,1) — Eq. 3
    a = a + (1.0 - m) * (-1e9)
    w = np.exp(a - a.max(axis=-2, keepdims=True))
    w = w / w.sum(axis=-2, keepdims=True)  # Eq. 4 cleanup
    return w * v


def split_heads(x, heads):
    b, t, e = x.shape
    return x.reshape(b, t, heads, e // heads).transpose(0, 2, 1, 3)


def merge_heads(x):
    b, h, t, hp = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, t, h * hp)


def forward(cfg, params, ids):
    p = {name: arr.astype(np.float64) for name, arr in params}
    b, t = ids.shape
    e, heads = cfg["embed"], cfg["heads"]
    mask = (ids != PAD_ID).astype(np.float64)

    x = p["embed.table"][np.clip(ids, 0, cfg["vocab"] - 1)]
    if cfg["pos"] == "learned":
        x = x + p["pos.table"][:t][None, :, :]
    else:
        x = x + sinusoid_positions(t, e)[None, :, :]

    for i in range(cfg["layers"]):
        n = f"blocks.{i}."
        h = layernorm(x, p[n + "ln1.scale"], p[n + "ln1.bias"])
        q = split_heads(h @ p[n + "mixer.query.kernel"], heads)
        k = split_heads(h @ p[n + "mixer.key.kernel"], heads)
        v = split_heads(h @ p[n + "mixer.value.kernel"], heads)
        mixed = merge_heads(hrr_attention(q, k, v, mask))
        x = x + mixed @ p[n + "mixer.output.kernel"]
        h = layernorm(x, p[n + "ln2.scale"], p[n + "ln2.bias"])
        h = gelu_tanh(h @ p[n + "mlp.fc1.kernel"] + p[n + "mlp.fc1.bias"])
        x = x + h @ p[n + "mlp.fc2.kernel"] + p[n + "mlp.fc2.bias"]

    x = layernorm(x, p["ln_f.scale"], p["ln_f.bias"])
    denom = np.maximum(mask.sum(axis=1, keepdims=True), 1.0)
    pooled = (x * mask[..., None]).sum(axis=1) / denom
    h = np.maximum(pooled @ p["head1.kernel"] + p["head1.bias"], 0.0)
    return h @ p["head2.kernel"] + p["head2.bias"]


# ---------------------------------------------------------------------------
# Parameter generation (canonical rust layout, f32 values)
# ---------------------------------------------------------------------------


def make_params(cfg, rng):
    """Ordered [(name, f32 array)] matching rust param_specs()."""
    e, mlp = cfg["embed"], cfg["mlp_dim"]

    def glorot(shape):
        scale = np.sqrt(2.0 / (shape[0] + shape[-1]))
        return (rng.standard_normal(shape) * scale).astype(np.float32)

    def normal(shape, std):
        return (rng.standard_normal(shape) * std).astype(np.float32)

    out = [("embed.table", normal((cfg["vocab"], e), 1.0 / np.sqrt(e)))]
    if cfg["pos"] == "learned":
        out.append(("pos.table", normal((cfg["seq_len"], e), 0.02)))
    for i in range(cfg["layers"]):
        n = f"blocks.{i}."
        # non-unit scales / non-zero LN+bias params so the fixture
        # actually exercises those code paths
        out.append((n + "ln1.scale", normal((e,), 0.1) + 1.0))
        out.append((n + "ln1.bias", normal((e,), 0.05)))
        out.append((n + "mixer.query.kernel", glorot((e, e))))
        out.append((n + "mixer.key.kernel", glorot((e, e))))
        out.append((n + "mixer.value.kernel", glorot((e, e))))
        out.append((n + "mixer.output.kernel", glorot((e, e))))
        out.append((n + "ln2.scale", normal((e,), 0.1) + 1.0))
        out.append((n + "ln2.bias", normal((e,), 0.05)))
        out.append((n + "mlp.fc1.kernel", glorot((e, mlp))))
        out.append((n + "mlp.fc1.bias", normal((mlp,), 0.05)))
        out.append((n + "mlp.fc2.kernel", glorot((mlp, e))))
        out.append((n + "mlp.fc2.bias", normal((e,), 0.05)))
    out.append(("ln_f.scale", normal((e,), 0.1) + 1.0))
    out.append(("ln_f.bias", normal((e,), 0.05)))
    out.append(("head1.kernel", glorot((e, mlp))))
    out.append(("head1.bias", normal((mlp,), 0.05)))
    out.append(("head2.kernel", glorot((mlp, cfg["classes"]))))
    out.append(("head2.bias", normal((cfg["classes"],), 0.05)))
    return [(name, arr.astype(np.float32)) for name, arr in out]


def export(name, cfg, seed):
    rng = np.random.default_rng(seed)
    params = make_params(cfg, rng)
    b, t = cfg["batch"], cfg["seq_len"]
    ids = rng.integers(1, cfg["vocab"], size=(b, t)).astype(np.int32)
    # trailing PAD on the last row exercises the mask everywhere
    ids[-1, t - t // 3 :] = PAD_ID
    logits = forward(cfg, params, ids)

    doc = {
        "name": name,
        "seed": seed,
        "config": cfg,
        "ids": ids.tolist(),
        "params": [
            {
                "name": pname,
                "shape": list(arr.shape),
                "data": [float(v) for v in arr.reshape(-1)],
            }
            for pname, arr in params
        ],
        "logits": [[float(v) for v in row] for row in logits],
        "tolerance": 1e-4,
    }
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(doc, f)
    print(f"wrote {path}: B={b} T={t} E={cfg['embed']} heads={cfg['heads']} "
          f"layers={cfg['layers']} -> logits {np.asarray(logits).shape}")


def main():
    # power-of-two head dim (radix-2 FFT path), fixed sinusoid positions
    export(
        "golden_hrr_fixed",
        {
            "task": "golden",
            "vocab": 11,
            "seq_len": 12,
            "batch": 2,
            "embed": 16,
            "mlp_dim": 32,
            "heads": 2,
            "layers": 2,
            "classes": 4,
            "pos": "fixed",
        },
        seed=20230701,
    )
    # non-power-of-two head dim (naive-DFT fallback), learned positions
    export(
        "golden_hrr_learned",
        {
            "task": "golden",
            "vocab": 9,
            "seq_len": 10,
            "batch": 2,
            "embed": 12,
            "mlp_dim": 16,
            "heads": 2,
            "layers": 1,
            "classes": 3,
            "pos": "learned",
        },
        seed=777,
    )


if __name__ == "__main__":
    main()
