//! # hrrformer — Recasting Self-Attention with Holographic Reduced Representations
//!
//! Rust coordinator + PJRT runtime for the ICML 2023 Hrrformer paper.
//! Three layers (DESIGN.md): Pallas HRR kernels (L1) and the JAX encoder
//! zoo (L2) are AOT-lowered to HLO text at build time; this crate (L3)
//! owns everything on the request path — datasets, training orchestration
//! (`coordinator`), the typed inference service (`engine`, one parallel
//! executor thread per sequence bucket), and the paper's benchmark
//! harness.
//!
//! Inference runs on one of two interchangeable backends behind the
//! `model::Predictor` trait: the AOT/PJRT artifact path above, or the
//! pure-Rust `hrr` module (FFT binding kernels + full Hrrformer forward
//! pass) selected with `engine::Backend::Native` — no artifacts needed.

// Deliberate idioms the clippy gate (verify.sh: `-D warnings`) should not
// fight: collection-like types without an is_empty use-case, and builders
// whose `new` mirrors an explicit `Default`.
#![allow(clippy::len_without_is_empty)]

pub mod analysis;
pub mod bench;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod hrr;
pub mod metrics;
pub mod model;
pub mod net;
pub mod runtime;
pub mod stream;
pub mod util;
