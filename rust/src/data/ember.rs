//! Synthetic PE-like malware corpus (EMBER substitution — DESIGN.md §3).
//!
//! The real EMBER corpus is 1 TB of labelled Windows executables; we
//! generate structurally PE-like byte streams that preserve the property
//! the paper's Figure 1 tests: **the label depends on long-range
//! co-occurrence of capabilities across different file regions**, which
//! defeats local windows and aggressive sequence compression.
//!
//! File layout: DOS header magic + PE header + section table, then
//! sections of several kinds:
//!   * `code`   — opcode-like bytes with realistic digraph statistics
//!   * `data`   — ASCII-ish strings and zero runs
//!   * `packed` — high-entropy xorshift bytes (appears in BOTH classes —
//!     packing is not malice, as Aghakhani et al. stress)
//!
//! Malicious files plant ≥2 *distinct* capability motifs (crypto loop,
//! network beacon, registry persistence, shell-spawn) in *different*
//! sections. Benign files plant at most one motif (legit software uses
//! crypto or networking, rarely the combination + persistence).
//!
//! Tokens are bytes+1, PAD=0, vocab 257 — identical to the paper's setup.

use crate::data::{Dataset, Example};
use crate::util::rng::Rng;

/// Capability motifs: short distinctive byte signatures, repeated with
/// small mutations so the model can't just memorize one offset.
const MOTIF_CRYPTO: &[u8] = &[0x31, 0xC0, 0x33, 0xD2, 0xC1, 0xE8, 0x07, 0x35, 0x20, 0x83, 0xF0, 0x4B];
const MOTIF_NETWORK: &[u8] = b"POST /gate.php HTTP/1.1";
const MOTIF_PERSIST: &[u8] = b"Software\\Microsoft\\Windows\\CurrentVersion\\Run";
const MOTIF_SHELL: &[u8] = b"cmd.exe /c start ";
const MOTIFS: [&[u8]; 4] = [MOTIF_CRYPTO, MOTIF_NETWORK, MOTIF_PERSIST, MOTIF_SHELL];

const BENIGN_STRINGS: &[&str] = &[
    "KERNEL32.dll", "GetProcAddress", "LoadLibraryA", "MessageBoxW",
    "C:\\Program Files\\Common\\", "Copyright (c) ", "VERSION_INFO",
    "mscoree.dll", "advapi32.dll", ".rsrc", "Segoe UI",
];

pub struct EmberSynth {
    pub max_len: usize,
}

impl EmberSynth {
    pub fn new(max_len: usize) -> EmberSynth {
        EmberSynth { max_len }
    }

    fn header(&self, rng: &mut Rng, out: &mut Vec<u8>) {
        out.extend_from_slice(b"MZ");
        out.extend_from_slice(&[0x90, 0x00, 0x03, 0x00]);
        for _ in 0..26 {
            out.push(rng.below(4) as u8);
        }
        out.extend_from_slice(b"PE\0\0");
        // COFF-ish fields
        out.extend_from_slice(&(rng.below(6) as u16 + 2).to_le_bytes()); // nsections
        out.extend_from_slice(&(rng.next_u32()).to_le_bytes()); // timestamp
    }

    fn code_bytes(&self, rng: &mut Rng, n: usize, out: &mut Vec<u8>) {
        // opcode-like digraphs: mov/push/call/ret densities
        const OPS: &[u8] = &[0x8B, 0x89, 0x55, 0x50, 0x51, 0xE8, 0xC3, 0x83, 0xFF, 0x74, 0x75, 0x90];
        for _ in 0..n {
            if rng.bool(0.6) {
                out.push(*rng.choose(OPS));
            } else {
                out.push(rng.below(256) as u8);
            }
        }
    }

    fn data_bytes(&self, rng: &mut Rng, n: usize, out: &mut Vec<u8>) {
        let end = out.len() + n;
        while out.len() < end {
            if rng.bool(0.5) {
                out.extend_from_slice(rng.choose(BENIGN_STRINGS).as_bytes());
                out.push(0);
            } else {
                let run = 4 + rng.usize_below(24);
                out.extend(std::iter::repeat(0u8).take(run));
            }
        }
        out.truncate(end);
    }

    fn packed_bytes(&self, rng: &mut Rng, n: usize, out: &mut Vec<u8>) {
        let mut state = rng.next_u64() | 1;
        for _ in 0..n {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            out.push((state >> 32) as u8);
        }
    }

    fn plant_motif(&self, rng: &mut Rng, out: &mut [u8], motif: &[u8]) {
        if out.len() <= motif.len() + 8 {
            return;
        }
        // 1-3 mutated copies at random offsets inside the section
        let copies = 1 + rng.usize_below(3);
        for _ in 0..copies {
            let pos = rng.usize_below(out.len() - motif.len());
            for (i, &b) in motif.iter().enumerate() {
                // 5% byte mutation — signatures in the wild drift
                out[pos + i] = if rng.bool(0.05) { rng.below(256) as u8 } else { b };
            }
        }
    }
}

impl Dataset for EmberSynth {
    fn name(&self) -> &'static str {
        "ember"
    }

    fn vocab(&self) -> usize {
        257
    }

    fn classes(&self) -> usize {
        2
    }

    fn sample(&self, rng: &mut Rng) -> Example {
        let malicious = rng.bool(0.5);
        let mut bytes: Vec<u8> = Vec::with_capacity(self.max_len);
        self.header(rng, &mut bytes);

        // sections fill the remaining budget
        let nsect = 3 + rng.usize_below(3);
        let budget = self.max_len.saturating_sub(bytes.len());
        let mut section_spans: Vec<(usize, usize)> = Vec::new();
        for s in 0..nsect {
            let len = if s == nsect - 1 {
                self.max_len - bytes.len()
            } else {
                (budget / nsect).max(32).min(self.max_len - bytes.len())
            };
            let start = bytes.len();
            match rng.below(3) {
                0 => self.code_bytes(rng, len, &mut bytes),
                1 => self.data_bytes(rng, len, &mut bytes),
                _ => self.packed_bytes(rng, len, &mut bytes),
            }
            section_spans.push((start, bytes.len()));
            if bytes.len() >= self.max_len {
                break;
            }
        }
        bytes.truncate(self.max_len);

        // capability planting: ≥2 distinct motifs in DIFFERENT sections
        // for malware; ≤1 motif for benign.
        let usable: Vec<(usize, usize)> =
            section_spans.iter().cloned().filter(|(a, b)| b - a > 64).collect();
        if malicious && usable.len() >= 2 {
            let mut motif_idx: Vec<usize> = (0..MOTIFS.len()).collect();
            rng.shuffle(&mut motif_idx);
            let n_caps = 2 + rng.usize_below(MOTIFS.len() - 1);
            let mut sect_idx: Vec<usize> = (0..usable.len()).collect();
            rng.shuffle(&mut sect_idx);
            for (i, &mi) in motif_idx.iter().take(n_caps).enumerate() {
                let (a, b) = usable[sect_idx[i % usable.len()]];
                self.plant_motif(rng, &mut bytes[a..b], MOTIFS[mi]);
            }
        } else if !usable.is_empty() && rng.bool(0.45) {
            // benign: possibly one lone capability (crypto OR network)
            let (a, b) = *rng.choose(&usable);
            let mi = rng.usize_below(2);
            self.plant_motif(rng, &mut bytes[a..b], MOTIFS[mi]);
        }

        let ids = bytes.iter().map(|&b| b as i32 + 1).collect();
        Example { ids, label: malicious as i32 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    fn count_motifs(bytes: &[u8]) -> usize {
        // count distinct motif families present (allowing the 5% mutation
        // by requiring 80% byte match at some offset)
        MOTIFS
            .iter()
            .filter(|m| {
                bytes.windows(m.len()).any(|w| {
                    let hits = w.iter().zip(m.iter()).filter(|(a, b)| a == b).count();
                    hits * 10 >= m.len() * 8
                })
            })
            .count()
    }

    #[test]
    fn well_formed_pe_like() {
        let ds = EmberSynth::new(2048);
        forall(40, 0xE3B, |rng| {
            let ex = ds.sample(rng);
            assert_eq!(ex.ids.len(), 2048);
            assert!(ex.ids.iter().all(|&t| (1..=256).contains(&t)));
            // DOS magic survives tokenization: 'M'+1, 'Z'+1
            assert_eq!(ex.ids[0], b'M' as i32 + 1);
            assert_eq!(ex.ids[1], b'Z' as i32 + 1);
        });
    }

    #[test]
    fn label_correlates_with_multi_capability() {
        let ds = EmberSynth::new(4096);
        let mut rng = Rng::new(21);
        let (mut mal_multi, mut mal_n) = (0usize, 0usize);
        let (mut ben_multi, mut ben_n) = (0usize, 0usize);
        for _ in 0..200 {
            let ex = ds.sample(&mut rng);
            let bytes: Vec<u8> = ex.ids.iter().map(|&t| (t - 1) as u8).collect();
            let multi = count_motifs(&bytes) >= 2;
            if ex.label == 1 {
                mal_n += 1;
                mal_multi += multi as usize;
            } else {
                ben_n += 1;
                ben_multi += multi as usize;
            }
        }
        let mal_rate = mal_multi as f64 / mal_n.max(1) as f64;
        let ben_rate = ben_multi as f64 / ben_n.max(1) as f64;
        assert!(
            mal_rate > ben_rate + 0.5,
            "capability co-occurrence signal too weak: mal={mal_rate:.2} ben={ben_rate:.2}"
        );
    }

    #[test]
    fn packed_sections_present_in_both_classes() {
        // high-entropy regions must not be a label shortcut
        let ds = EmberSynth::new(4096);
        let mut rng = Rng::new(22);
        let entropy = |bytes: &[u8]| -> f64 {
            let mut hist = [0f64; 256];
            for &b in bytes {
                hist[b as usize] += 1.0;
            }
            let n = bytes.len() as f64;
            hist.iter()
                .filter(|&&c| c > 0.0)
                .map(|&c| {
                    let p = c / n;
                    -p * p.log2()
                })
                .sum()
        };
        let mut high_entropy = [0usize; 2];
        let mut counts = [0usize; 2];
        for _ in 0..200 {
            let ex = ds.sample(&mut rng);
            let bytes: Vec<u8> = ex.ids.iter().map(|&t| (t - 1) as u8).collect();
            // max window entropy over 512-byte windows
            let max_h = bytes.chunks(512).map(|w| entropy(w)).fold(0.0, f64::max);
            counts[ex.label as usize] += 1;
            if max_h > 7.5 {
                high_entropy[ex.label as usize] += 1;
            }
        }
        let r0 = high_entropy[0] as f64 / counts[0].max(1) as f64;
        let r1 = high_entropy[1] as f64 / counts[1].max(1) as f64;
        assert!((r0 - r1).abs() < 0.3, "entropy is a label shortcut: {r0:.2} vs {r1:.2}");
    }

    #[test]
    fn scales_to_long_sequences() {
        let ds = EmberSynth::new(131_072);
        let mut rng = Rng::new(23);
        let ex = ds.sample(&mut rng);
        assert_eq!(ex.ids.len(), 131_072);
    }
}
