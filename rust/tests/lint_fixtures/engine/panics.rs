//! hrrlint fixture: panic-path + unbounded-channel seeded violations in
//! an `engine/`-scoped path. This file is lint fixture *data* — it is
//! walked by the linter, never compiled by cargo.

use std::collections::HashMap;
use std::sync::mpsc::{channel, sync_channel};

pub fn serve(map: &HashMap<u32, u32>) -> u32 {
    let v = map.get(&1).unwrap(); // FIXTURE: panic-path (unwrap)
    let w = map.get(&2).expect("missing"); // FIXTURE: panic-path (expect)
    if *v > *w {
        panic!("order violated"); // FIXTURE: panic-path (panic!)
    }
    match v {
        0 => unreachable!(), // FIXTURE: panic-path (unreachable!)
        _ => *v + *w,
    }
}

pub fn queues() -> usize {
    let (tx, rx) = channel::<u32>(); // FIXTURE: unbounded-channel (turbofish)
    let (tx2, rx2) = sync_channel::<u32>(4); // ok: bounded
    drop((tx, tx2, rx2));
    rx.try_iter().count()
}

pub fn recovered(v: std::sync::Mutex<u32>) -> u32 {
    // The explicit poisoned-lock recovery idiom must NOT fire: the
    // method identifier is `unwrap_or_else`, not `unwrap`.
    *v.lock().unwrap_or_else(|p| p.into_inner())
}

pub fn suppressed(v: Option<u32>) -> u32 {
    // hrrlint: allow(panic-path)
    v.unwrap() // suppressed by the allow() on the line above
}

pub fn strings_and_comments() -> &'static str {
    // a comment mentioning unwrap() and panic!("nope") must not fire
    "call .unwrap() and panic!(\"boom\") inside a string" // no finding
}

#[cfg(not(test))]
pub fn not_test_guarded(v: Option<u32>) -> u32 {
    // cfg(not(test)) is real code: this MUST still fire.
    v.unwrap() // FIXTURE: panic-path (under cfg(not(test)))
}

#[cfg(test)]
mod tests {
    #[test]
    fn panics_are_fine_here() {
        let v: Option<u32> = None;
        let _ = v.unwrap(); // exempt: inside #[cfg(test)]
        panic!("test-only"); // exempt: inside #[cfg(test)]
    }
}
