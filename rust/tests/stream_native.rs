//! Streaming-subsystem integration: the chunked multi-pass forward must
//! be **bit-identical** to the whole-row native forward on the golden
//! fixtures (both FFT paths, PAD masking in play), the engine's
//! open/append/finish lifecycle must serve a classification end-to-end
//! with typed lifecycle errors, and the carried per-stream state must be
//! O(H) — the same byte count no matter how long the bucket is.
//!
//! Always runs: no artifacts, no PJRT, no skips.

use std::time::Duration;

use hrrformer::data::mmap::{write_corpus, MmapCorpus};
use hrrformer::data::{by_task, Split};
use hrrformer::engine::{Engine, EngineError};
use hrrformer::hrr::{HrrConfig, NativeSession};
use hrrformer::model::ParamStore;
use hrrformer::runtime::Tensor;
use hrrformer::stream::{classify_source, SliceSource, StreamConfig, StreamError};
use hrrformer::util::json::Json;

/// Parse an exported golden fixture into (config, params, ids) — the
/// same format golden_native.rs checks against the Python reference;
/// here the whole-row forward is itself the reference and the chunked
/// stream must match it *bitwise*, not within tolerance.
fn load_fixture(text: &str) -> (HrrConfig, ParamStore, Vec<Vec<i32>>) {
    let j = Json::parse(text).expect("fixture json parses");
    let cfgj = j.get("config").expect("config");
    let u = |k: &str| cfgj.get(k).and_then(Json::as_usize).unwrap_or_else(|| panic!("config.{k}"));
    let cfg = HrrConfig {
        // streaming is hrrformer-only (the golden fixtures are too)
        arch: hrrformer::hrr::Arch::Hrrformer,
        task: cfgj.get("task").and_then(Json::as_str).unwrap_or("golden").to_string(),
        vocab: u("vocab"),
        seq_len: u("seq_len"),
        batch: u("batch"),
        embed: u("embed"),
        mlp_dim: u("mlp_dim"),
        heads: u("heads"),
        layers: u("layers"),
        classes: u("classes"),
        learned_pos: cfgj.get("pos").and_then(Json::as_str) == Some("learned"),
    };

    let mut params = ParamStore::default();
    for p in j.get("params").and_then(Json::as_arr).expect("params") {
        let name = p.get("name").and_then(Json::as_str).expect("param.name").to_string();
        let shape: Vec<usize> = p
            .get("shape")
            .and_then(Json::as_arr)
            .expect("param.shape")
            .iter()
            .map(|d| d.as_usize().expect("shape dim"))
            .collect();
        let data: Vec<f32> = p
            .get("data")
            .and_then(Json::as_arr)
            .expect("param.data")
            .iter()
            .map(|v| v.as_f64().expect("param value") as f32)
            .collect();
        params.names.push(name);
        params.tensors.push(Tensor::f32(shape, data));
    }

    let rows: Vec<Vec<i32>> = j
        .get("ids")
        .and_then(Json::as_arr)
        .expect("ids")
        .iter()
        .map(|row| row.as_arr().expect("ids row").iter().map(|v| v.as_i64().unwrap() as i32).collect())
        .collect();
    (cfg, params, rows)
}

/// Chunk sizes that stress the boundary logic: single-token, a prime
/// that never divides T, a power of two, and the whole row at once.
fn chunk_sweep(t: usize) -> [usize; 4] {
    [1, 7, 16, t]
}

fn check_fixture_stream_parity(text: &str, label: &str) {
    let (cfg, params, rows) = load_fixture(text);
    let sess = NativeSession::with_params(cfg.clone(), params)
        .unwrap_or_else(|e| panic!("{label}: fixture params rejected: {e:#}"));
    for (r, ids) in rows.iter().enumerate() {
        let t = ids.len();
        let whole = sess
            .predict(&Tensor::i32(vec![1, t], ids.clone()))
            .unwrap_or_else(|e| panic!("{label}: whole-row predict failed: {e:#}"));
        let want = whole.as_f32().unwrap();
        for chunk in chunk_sweep(t) {
            let mut src = SliceSource::new(ids);
            let (got, st) = classify_source(&sess, &mut src, chunk)
                .unwrap_or_else(|e| panic!("{label}: chunked forward failed: {e:#}"));
            assert_eq!(
                got.as_slice(),
                want,
                "{label}: row {r} chunk {chunk}: chunked logits differ from whole-row bitwise"
            );
            assert!(st.ready(), "{label}: all passes must complete");
            assert_eq!(st.tokens(), t, "{label}: token count carried in state");
        }
    }
    eprintln!("{label}: chunked forward bit-identical across chunk sizes [1, 7, 16, T]");
}

#[test]
fn chunked_stream_matches_whole_row_on_pow2_fft_fixture() {
    check_fixture_stream_parity(include_str!("fixtures/golden_hrr_fixed.json"), "golden_hrr_fixed");
}

#[test]
fn chunked_stream_matches_whole_row_on_naive_dft_fixture() {
    check_fixture_stream_parity(
        include_str!("fixtures/golden_hrr_learned.json"),
        "golden_hrr_learned",
    );
}

/// Fresh spool dir per test so parallel test threads never collide.
fn test_stream_cfg(name: &str) -> StreamConfig {
    let dir = std::env::temp_dir().join("hrrformer_stream_native_test").join(name);
    StreamConfig { chunk_cap: 16, ..StreamConfig::new(dir) }
}

const BASE: &str = "ember_hrrformer_small_T64_B1";
const SEED: u32 = 9;

#[test]
fn engine_stream_lifecycle_classifies_end_to_end() {
    let engine = Engine::builder()
        .stream_bucket(BASE)
        .stream_config(test_stream_cfg("lifecycle"))
        .seed(SEED)
        .build_native()
        .expect("stream-only native engine builds");

    // 100 bytes into a T=64 bucket: appended in uneven pieces, truncated
    // at the bucket length, classified on finish.
    let bytes: Vec<u8> = (0..100u32).map(|i| (i * 37 % 251) as u8).collect();
    let id = engine.open_stream().expect("open");
    for piece in bytes.chunks(13) {
        engine.append_stream(id, piece).expect("append");
    }
    let out = engine.finish_stream(id).expect("finish");
    assert_eq!(out.appended, 100);
    assert_eq!(out.tokens, 64, "stream truncates at the bucket T");
    assert!(out.truncated);

    // The engine-served logits must equal the direct kernel forward on
    // the same (truncated) tokens, bitwise — same base, same seed.
    let sess = NativeSession::create(BASE, SEED).unwrap();
    let ids: Vec<i32> = bytes[..64].iter().map(|&b| b as i32 + 1).collect();
    let want = sess.predict(&Tensor::i32(vec![1, 64], ids)).unwrap();
    assert_eq!(out.logits.as_slice(), want.as_f32().unwrap(), "engine path = kernel path bitwise");

    // Lifecycle errors are typed and distinguish *why* an id is gone.
    assert_eq!(
        engine.append_stream(id, &b"late"[..]),
        Err(EngineError::Stream(StreamError::Finished(id)))
    );
    assert_eq!(
        engine.finish_stream(9999),
        Err(EngineError::Stream(StreamError::Unknown(9999)))
    );
    engine.stop();
}

#[test]
fn mmap_fed_streams_match_direct_kernel_bitwise() {
    // The paper-scale workload in miniature: a memory-mapped corpus
    // feeds engine streams chunk by chunk; no full row is ever
    // materialized on the append path.
    let dir = std::env::temp_dir().join("hrrformer_stream_native_test");
    std::fs::create_dir_all(&dir).unwrap();
    let corpus_path = dir.join("mmap_corpus.bin");
    let ds = by_task("ember", 64).unwrap();
    write_corpus(&corpus_path, ds.as_ref(), Split::Test, 5, 2, 64).unwrap();
    let corpus = MmapCorpus::open(&corpus_path).unwrap();

    let engine = Engine::builder()
        .stream_bucket(BASE)
        .stream_config(test_stream_cfg("mmap"))
        .seed(SEED)
        .build_native()
        .unwrap();
    let sess = NativeSession::create(BASE, SEED).unwrap();

    for r in 0..corpus.len() {
        let id = engine.open_stream().unwrap();
        let mut buf = vec![0u8; 13]; // prime-sized pieces off the mapping
        let mut off = 0usize;
        loop {
            let got = corpus.read_row_chunk(r, off, &mut buf).unwrap();
            if got == 0 {
                break;
            }
            engine.append_stream(id, &buf[..got]).unwrap();
            off += got;
        }
        let out = engine.finish_stream(id).unwrap();
        let (want, _) = classify_source(&sess, &mut corpus.row_source(r).unwrap(), 16).unwrap();
        assert_eq!(out.logits, want, "row {r}: engine stream = mmap kernel path bitwise");
        assert!(!out.truncated);
        assert_eq!(out.tokens, 64);
    }
    engine.stop();
    let _ = std::fs::remove_file(&corpus_path);
}

#[test]
fn idle_streams_are_evicted_by_the_engine_sweeper() {
    // Zero idle timeout: the executor's sweep (which runs after every
    // message) evicts the stream before the next call arrives — no
    // sleeping in the test.
    let cfg = StreamConfig { idle_timeout: Duration::ZERO, ..test_stream_cfg("evict") };
    let engine = Engine::builder()
        .stream_bucket(BASE)
        .stream_config(cfg)
        .seed(SEED)
        .build_native()
        .unwrap();
    let id = engine.open_stream().unwrap();
    assert_eq!(
        engine.append_stream(id, &b"hello"[..]),
        Err(EngineError::Stream(StreamError::Evicted(id)))
    );
    engine.stop();
}

#[test]
fn stream_calls_without_a_stream_bucket_are_typed_unavailable() {
    let engine = Engine::builder().bucket(BASE).seed(SEED).build_native().unwrap();
    assert_eq!(engine.open_stream(), Err(EngineError::StreamUnavailable));
    assert_eq!(engine.append_stream(0, &b"x"[..]), Err(EngineError::StreamUnavailable));
    assert_eq!(engine.finish_stream(0), Err(EngineError::StreamUnavailable));
    engine.stop();
}

#[test]
fn hgconv_stream_buckets_fail_at_engine_build_naming_the_arch() {
    // streaming is an architecture capability; a misconfigured hgconv
    // stream bucket must fail loudly at build time, not at first open
    let err = Engine::builder()
        .stream_bucket("ember_hgconv_small_T64_B1")
        .stream_config(test_stream_cfg("hgconv_reject"))
        .seed(SEED)
        .build_native()
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("does not support streaming"), "untyped build error: {msg}");
    assert!(msg.contains("hgconv"), "the error must name the architecture: {msg}");
}

#[test]
fn carried_state_is_o_h_independent_of_bucket_length() {
    // The subsystem's core claim: per-stream resident state depends on
    // the model (heads, bins, embed), never on T. Compare buckets 64×
    // apart in sequence length.
    let small = NativeSession::create("ember_hrrformer_small_T64_B1", SEED).unwrap();
    let large = NativeSession::create("ember_hrrformer_small_T4096_B1", SEED).unwrap();
    let a = small.stream_state().resident_bytes();
    let b = large.stream_state().resident_bytes();
    assert!(a > 0);
    assert_eq!(a, b, "resident stream state must not grow with T ({a} vs {b} bytes)");
}
