//! Document-pair matching (LRA Retrieval substitution, DESIGN.md §3).
//!
//! Two byte-level "papers" are generated; positives share a planted
//! citation key (a 12-byte identifier appearing once in each document at
//! a random offset), negatives have unrelated keys. The pair is encoded
//! as `doc1 SEP doc2` in one fixed-length sequence — the model must
//! compress-then-compare across thousands of bytes.

use crate::data::{Dataset, Example};
use crate::util::rng::Rng;

const SEP: i32 = 256; // byte 255 + 1 = 256 is reserved as separator
const FILLER_WORDS: &[&str] = &[
    "method", "results", "analysis", "model", "data", "experiment", "figure",
    "table", "baseline", "approach", "significant", "propose", "evaluate",
    "benchmark", "训练", "sequence", "attention", "accuracy", "novel",
];

pub struct Retrieval {
    /// Total sequence length (both documents + separator).
    pub max_len: usize,
}

impl Retrieval {
    pub fn new(max_len: usize) -> Retrieval {
        Retrieval { max_len }
    }

    fn citation_key(rng: &mut Rng) -> Vec<u8> {
        // e.g. "[@K4X9QZ2B]" — distinctive bracketed key
        let mut key = b"[@".to_vec();
        for _ in 0..8 {
            let c = b"ABCDEFGHJKLMNPQRSTUVWXYZ23456789"[rng.usize_below(32)];
            key.push(c);
        }
        key.push(b']');
        key
    }

    fn doc(&self, rng: &mut Rng, len: usize, key: &[u8]) -> Vec<u8> {
        let mut text: Vec<u8> = Vec::with_capacity(len);
        while text.len() < len {
            text.extend_from_slice(rng.choose(FILLER_WORDS).as_bytes());
            text.push(b' ');
        }
        text.truncate(len);
        // plant the key at a random position
        if len > key.len() {
            let pos = rng.usize_below(len - key.len());
            text[pos..pos + key.len()].copy_from_slice(key);
        }
        text
    }
}

impl Dataset for Retrieval {
    fn name(&self) -> &'static str {
        "retrieval"
    }

    fn vocab(&self) -> usize {
        257
    }

    fn classes(&self) -> usize {
        2
    }

    fn sample(&self, rng: &mut Rng) -> Example {
        let doc_len = (self.max_len - 1) / 2;
        let matched = rng.bool(0.5);
        let key1 = Self::citation_key(rng);
        let key2 = if matched { key1.clone() } else { Self::citation_key(rng) };
        let d1 = self.doc(rng, doc_len, &key1);
        let d2 = self.doc(rng, doc_len, &key2);
        let mut ids: Vec<i32> = Vec::with_capacity(self.max_len);
        ids.extend(d1.iter().map(|&b| b as i32 + 1));
        ids.push(SEP);
        ids.extend(d2.iter().map(|&b| b as i32 + 1));
        Example { ids, label: matched as i32 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn structure_and_key_plant() {
        let ds = Retrieval::new(512);
        forall(50, 0xD0C5, |rng| {
            let ex = ds.sample(rng);
            assert!(ex.ids.len() <= 512);
            let seps = ex.ids.iter().filter(|&&t| t == SEP).count();
            assert!(seps >= 1, "separator missing");
            // decode and check key sharing matches the label
            let text: Vec<u8> = ex.ids.iter().map(|&t| (t - 1).max(0) as u8).collect();
            let s = String::from_utf8_lossy(&text);
            let keys: Vec<&str> = s
                .match_indices("[@")
                .filter_map(|(i, _)| s.get(i..i + 11))
                .collect();
            assert_eq!(keys.len(), 2, "expected two planted keys in {s}");
            assert_eq!((keys[0] == keys[1]) as i32, ex.label);
        });
    }

    #[test]
    fn balanced_labels() {
        let ds = Retrieval::new(256);
        let mut rng = Rng::new(11);
        let pos: usize = (0..1000).map(|_| ds.sample(&mut rng).label as usize).sum();
        assert!((400..600).contains(&pos), "imbalanced: {pos}");
    }
}
