//! ListOps (Nangia & Bowman) — the actual LRA grammar, generated and
//! evaluated in rust: nested MAX / MIN / MED / SM (sum-mod-10) lists over
//! digits. Ten-way classification; tests hierarchical long-context
//! reasoning.
//!
//! Token map (vocab 18, matching the `listops` config):
//!   0 PAD · 1..=10 digits 0-9 · 11 [MAX · 12 [MIN · 13 [MED · 14 [SM · 15 ]

use crate::data::{Dataset, Example};
use crate::util::rng::Rng;

pub const PAD: i32 = 0;
pub const DIGIT0: i32 = 1;
pub const OPEN_MAX: i32 = 11;
pub const OPEN_MIN: i32 = 12;
pub const OPEN_MED: i32 = 13;
pub const OPEN_SM: i32 = 14;
pub const CLOSE: i32 = 15;

#[derive(Debug, Clone, Copy, PartialEq)]
enum Op {
    Max,
    Min,
    Med,
    Sm,
}

impl Op {
    fn token(self) -> i32 {
        match self {
            Op::Max => OPEN_MAX,
            Op::Min => OPEN_MIN,
            Op::Med => OPEN_MED,
            Op::Sm => OPEN_SM,
        }
    }

    fn eval(self, args: &[i64]) -> i64 {
        match self {
            Op::Max => *args.iter().max().unwrap(),
            Op::Min => *args.iter().min().unwrap(),
            Op::Med => {
                let mut v = args.to_vec();
                v.sort();
                v[v.len() / 2]
            }
            Op::Sm => args.iter().sum::<i64>() % 10,
        }
    }
}

/// ListOps generator with a hard maximum token length.
pub struct ListOps {
    pub max_len: usize,
    pub max_depth: usize,
    pub max_args: usize,
}

impl ListOps {
    pub fn new(max_len: usize) -> ListOps {
        ListOps { max_len, max_depth: 6, max_args: 6 }
    }

    fn rand_op(rng: &mut Rng) -> Op {
        match rng.below(4) {
            0 => Op::Max,
            1 => Op::Min,
            2 => Op::Med,
            _ => Op::Sm,
        }
    }

    /// Emit one expression into `out`, consuming at most `*remaining`
    /// tokens (invariant: every call emits ≥1 token and decrements
    /// `remaining` by exactly what it emits). Returns the value.
    fn gen(&self, rng: &mut Rng, depth: usize, out: &mut Vec<i32>, remaining: &mut i64) -> i64 {
        debug_assert!(*remaining >= 1);
        // a list needs open + close + two minimal args = 4 tokens
        let can_list = depth < self.max_depth && *remaining >= 4;
        if !can_list || !rng.bool(0.45) {
            let d = rng.below(10) as i64;
            out.push(DIGIT0 + d as i32);
            *remaining -= 1;
            return d;
        }
        let op = Self::rand_op(rng);
        out.push(op.token());
        *remaining -= 2; // open + close
        let mut args = Vec::new();
        while args.len() < 2 || (args.len() < self.max_args && *remaining > 2 && rng.bool(0.55)) {
            args.push(self.gen(rng, depth + 1, out, remaining));
            if *remaining < 1 {
                break;
            }
        }
        out.push(CLOSE);
        op.eval(&args)
    }
}

impl Dataset for ListOps {
    fn name(&self) -> &'static str {
        "listops"
    }

    fn vocab(&self) -> usize {
        18
    }

    fn classes(&self) -> usize {
        10
    }

    fn sample(&self, rng: &mut Rng) -> Example {
        // top level is always a list (as in the original dataset)
        let mut ids = Vec::with_capacity(self.max_len);
        let op = Self::rand_op(rng);
        ids.push(op.token());
        let mut remaining = self.max_len as i64 - 2; // open + close reserved
        let mut args = Vec::new();
        // keep the top-level list wide so examples use most of the length
        // budget (like the real LRA corpus, where sequences approach the
        // task's maximum); stop stochastically in the last quarter.
        let fill_floor = self.max_len as i64 / 4;
        while args.len() < 2 || remaining > fill_floor || (remaining > 2 && rng.bool(0.5)) {
            args.push(self.gen(rng, 1, &mut ids, &mut remaining));
            if remaining < 1 {
                break;
            }
        }
        ids.push(CLOSE);
        let label = op.eval(&args) as i32;
        debug_assert!(ids.len() <= self.max_len, "overflow: {}", ids.len());
        Example { ids, label }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn ops_evaluate_correctly() {
        assert_eq!(Op::Max.eval(&[1, 9, 3]), 9);
        assert_eq!(Op::Min.eval(&[4, 2, 8]), 2);
        assert_eq!(Op::Med.eval(&[5, 1, 9]), 5);
        assert_eq!(Op::Sm.eval(&[7, 8]), 5);
    }

    #[test]
    fn examples_are_well_formed() {
        let ds = ListOps::new(200);
        forall(100, 0xA11CE, |rng| {
            let ex = ds.sample(rng);
            assert!(ex.ids.len() <= 200, "too long: {}", ex.ids.len());
            assert!((0..10).contains(&ex.label));
            // balanced brackets
            let mut depth: i64 = 0;
            for &t in &ex.ids {
                assert!((DIGIT0..=CLOSE).contains(&t), "bad token {t}");
                if (OPEN_MAX..=OPEN_SM).contains(&t) {
                    depth += 1;
                }
                if t == CLOSE {
                    depth -= 1;
                    assert!(depth >= 0, "unbalanced close");
                }
            }
            assert_eq!(depth, 0, "unbalanced brackets");
        });
    }

    #[test]
    fn labels_cover_all_classes() {
        let ds = ListOps::new(300);
        let mut rng = Rng::new(5);
        let mut seen = [0usize; 10];
        for _ in 0..2000 {
            seen[ds.sample(&mut rng).label as usize] += 1;
        }
        for (d, &n) in seen.iter().enumerate() {
            assert!(n > 20, "class {d} underrepresented: {n}/2000");
        }
    }

    #[test]
    fn roundtrip_eval_matches_token_parse() {
        // parse the token stream back and evaluate — must equal label
        fn parse(ids: &[i32], pos: &mut usize) -> i64 {
            let t = ids[*pos];
            *pos += 1;
            if (DIGIT0..=DIGIT0 + 9).contains(&t) {
                return (t - DIGIT0) as i64;
            }
            let op = match t {
                OPEN_MAX => Op::Max,
                OPEN_MIN => Op::Min,
                OPEN_MED => Op::Med,
                OPEN_SM => Op::Sm,
                _ => panic!("bad open {t}"),
            };
            let mut args = Vec::new();
            while ids[*pos] != CLOSE {
                args.push(parse(ids, pos));
            }
            *pos += 1; // consume CLOSE
            op.eval(&args)
        }
        let ds = ListOps::new(400);
        let mut rng = Rng::new(17);
        for _ in 0..200 {
            let ex = ds.sample(&mut rng);
            let mut pos = 0;
            assert_eq!(parse(&ex.ids, &mut pos), ex.label as i64);
            assert_eq!(pos, ex.ids.len());
        }
    }
}
