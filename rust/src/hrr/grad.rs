//! The Adam optimizer and batch-level training loop over the shared
//! tape autodiff — artifact-free training ([`NativeTrainSession`]) for
//! every native architecture.
//!
//! The forward pass here **is** `common::forward_row_with` — train and
//! predict share one forward implementation per architecture, and the
//! tape side observes it through the `ForwardTap` hooks
//! (`common::tape::TapeRecorder`), keeping every intermediate backward
//! needs on a per-row `Tape`. Logits are bit-identical to predict's by
//! construction (still pinned by a test). `common::tape::backward_row`
//! then walks the tape in reverse:
//!
//! * softmax cross-entropy (model.py `loss_fn`: mean NLL over the batch);
//! * dense / bias / ReLU head, masked mean-pool, LayerNorm (recomputed
//!   μ/σ from the taped input), tanh-GELU — all architecture-neutral,
//!   in `common::tape`;
//! * the mixer adjoint, dispatched per architecture: the
//!   frequency-domain HRR attention adjoints (paper Eqs. 1-4) in
//!   `hrr::hrrformer`, the correlation-theorem adjoints of the gated
//!   FFT convolution in `hrr::hgconv`;
//! * embeddings scatter-add; learned positions accumulate directly;
//!   fixed sinusoids have no parameters.
//!
//! The hand-derived math is mirrored one-to-one by
//! `python/compile/export_golden.py::backward_row`, which self-checks
//! against central differences before exporting the golden train-curve
//! fixture (`rust/tests/fixtures/golden_hrr_train.json`) that
//! `golden_train.rs` replays through this module.
//!
//! # Determinism contract
//!
//! Batch rows are independent, so gradient work fans out through the
//! same [`RowScheduler`] seam `NativeSession::predict` uses. Every row
//! writes its gradients into its **own** f64 buffer; the batch gradient
//! is then reduced on the calling thread in ascending row order, in f64.
//! The reduction order never depends on which worker computed which row,
//! so gradients (and therefore the whole training trajectory) are
//! **bit-identical** across sequential, scoped and pool schedulers at
//! any worker budget — the same contract PR 3/4 established for predict.
//! The price is one parameter-sized f64 buffer per row in flight
//! (~`8·B·|θ|` bytes), which is what makes the fixed reduction order
//! possible at all.
//!
//! # Dropout
//!
//! [`NativeTrainSession::set_dropout`] enables inverted dropout on the
//! embedding and both residual branches of every block, active **only**
//! inside `train_step`. Masks derive from (seed, step, row, site) alone
//! (`common::DropoutCtx`), never from the scheduler or the worker a row
//! landed on, so dropped training keeps the bit-identical scheduler
//! contract — and eval / predict / serving paths never see dropout.
//!
//! # Optimizer
//!
//! Exactly the exported program's protocol (model.py `adam_update` /
//! `lr_schedule`): Adam with β₁=0.9, β₂=0.999, ε=1e-8, bias correction,
//! and exponential LR decay `max(lr · decay^(step/steps_per_epoch),
//! lr_min)` with the per-task decay rate from `configs.py`. Parameters
//! and both moments are stored f32; each update computes in f64 from the
//! stored f32 values and rounds once on the way back.

use std::path::Path;

use anyhow::{Context, Result};

use crate::hrr::common::tape::{
    backward_row, forward_row_tape, softmax_ce, GradScratch, RowGrads, Tape,
};
use crate::hrr::common::{
    forward_row, init_native_params, param_specs, validate_native_params, DropoutCtx, DropoutSpec,
    ResolvedParams, Workspace,
};
use crate::hrr::config::{task_decay_rate, HrrConfig};
use crate::hrr::RowScheduler;
use crate::model::artifact::{Artifact, Provenance};
use crate::model::params::ParamStore;
use crate::model::session::{Session, StepStats, Trainable};
use crate::runtime::tensor::Tensor;
use crate::util::pool::Task as PoolTask;

/// Adam's moment decays and ε — fixed, like the exported train_step
/// (model.py `adam_update` defaults).
const B1: f64 = 0.9;
const B2: f64 = 0.999;
const ADAM_EPS: f64 = 1e-8;

// ---------------------------------------------------------------------------
// Hyper-parameters (the exported program's training protocol)
// ---------------------------------------------------------------------------

/// Learning-rate schedule of the paper's protocol: exponential decay per
/// epoch from `lr` down to `lr_min` (model.py `lr_schedule`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TrainHyper {
    pub lr: f64,
    pub lr_min: f64,
    /// Per-epoch decay factor (task-dependent in configs.py).
    pub decay_rate: f64,
    /// Steps per "epoch" for the schedule (configs.py: 100).
    pub steps_per_epoch: f64,
}

impl Default for TrainHyper {
    fn default() -> Self {
        TrainHyper { lr: 1e-3, lr_min: 1e-5, decay_rate: 0.90, steps_per_epoch: 100.0 }
    }
}

impl TrainHyper {
    /// The schedule for one task, with the per-task decay rate from the
    /// preset tables.
    pub fn for_task(task: &str) -> TrainHyper {
        TrainHyper { decay_rate: task_decay_rate(task), ..TrainHyper::default() }
    }

    /// Learning rate at (0-based) optimizer step `step`.
    pub fn lr_at(&self, step: u32) -> f64 {
        (self.lr * self.decay_rate.powf(step as f64 / self.steps_per_epoch)).max(self.lr_min)
    }
}

/// Output slot of one training row.
struct RowOut {
    nll: f64,
    correct: bool,
    grads: RowGrads,
}

// ---------------------------------------------------------------------------
// Row scheduling (shared shape with NativeSession::predict)
// ---------------------------------------------------------------------------

/// Fan `rows` out in contiguous chunks through the scheduler; `f(row0,
/// chunk)` runs the identical per-row path everywhere, so outputs cannot
/// depend on the partitioning.
fn scatter_rows<T, F>(scheduler: &RowScheduler, rows: &mut [T], f: F) -> Result<()>
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let b = rows.len();
    if b == 0 {
        return Ok(());
    }
    match scheduler {
        RowScheduler::Sequential => f(0, rows),
        RowScheduler::Scoped(threads) => {
            let workers = (*threads).clamp(1, b);
            if workers == 1 {
                f(0, rows);
            } else {
                let rows_per = b.div_ceil(workers);
                let fref = &f;
                std::thread::scope(|s| -> Result<()> {
                    let handles: Vec<_> = rows
                        .chunks_mut(rows_per)
                        .enumerate()
                        .map(|(ci, chunk)| s.spawn(move || fref(ci * rows_per, chunk)))
                        .collect();
                    for h in handles {
                        h.join().map_err(|_| anyhow::anyhow!("native train worker panicked"))?;
                    }
                    Ok(())
                })?;
            }
        }
        RowScheduler::Pool(pool) => {
            // Oversubscribed chunk count (see `WorkerPool::task_chunks`):
            // skewed row costs stop straggling behind a static B/budget
            // split, and partitioning still can't change per-row math.
            let chunks = pool.task_chunks(b);
            let rows_per = b.div_ceil(chunks);
            let fref = &f;
            let tasks: Vec<PoolTask<'_>> = rows
                .chunks_mut(rows_per)
                .enumerate()
                .map(|(ci, chunk)| Box::new(move || fref(ci * rows_per, chunk)) as PoolTask<'_>)
                .collect();
            pool.run(tasks).map_err(|_| anyhow::anyhow!("native train worker panicked"))?;
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// NativeTrainSession
// ---------------------------------------------------------------------------

/// Artifact-free training session over the pure-Rust forward/backward
/// pass — the native counterpart of [`crate::model::TrainSession`],
/// usable anywhere a [`Trainable`] is (the trainer, benches, examples)
/// with no AOT artifacts and no PJRT runtime.
///
/// Owns parameters and Adam moments (all f32, like the exported
/// program's state) and a [`RowScheduler`] that fans each batch's
/// forward+backward rows out exactly like `NativeSession::predict` fans
/// inference rows. Gradients are reduced in fixed row order, so the
/// whole training trajectory is bit-identical under every scheduler and
/// worker budget. The architecture comes from the config — both the
/// paper's Hrrformer and the HGConv mixer train through this one
/// session type.
pub struct NativeTrainSession {
    cfg: HrrConfig,
    /// Program base this session was created from (empty when built
    /// from an explicit config) — recorded as artifact provenance.
    base: String,
    hyper: TrainHyper,
    params: ParamStore,
    m: ParamStore,
    v: ParamStore,
    step: u32,
    scheduler: RowScheduler,
    /// Drop probability for `train_step` (0 = disabled) and the seed
    /// its mask streams derive from.
    dropout: f64,
    dropout_seed: u64,
    /// Recycled per-row gradient buffers: [`NativeTrainSession::train_step`]
    /// returns each batch's `RowGrads` here instead of dropping them, so
    /// steady-state training stops reallocating ~B parameter-sized f64
    /// buffers every step. Zero-filled before reuse (the backward pass
    /// accumulates), so recycling cannot change a single gradient bit.
    grad_cache: Vec<RowGrads>,
}

impl NativeTrainSession {
    /// Resolve `base` (e.g. `listops_hrrformer_small_T512_B8`) against
    /// the native preset tables and seed-initialize parameters; the LR
    /// schedule picks the task's decay rate.
    pub fn create(base: &str, seed: u32) -> Result<NativeTrainSession> {
        let mut sess = Self::from_config(HrrConfig::from_base(base)?, seed)?;
        sess.base = base.to_string();
        Ok(sess)
    }

    /// Seed-initialize parameters for an explicit config.
    pub fn from_config(cfg: HrrConfig, seed: u32) -> Result<NativeTrainSession> {
        cfg.validate()?;
        let params = init_native_params(&cfg, seed);
        Self::with_params(cfg, params)
    }

    /// Train from explicit parameters (a checkpoint, or a golden
    /// fixture). Names and shapes must match [`param_specs`].
    pub fn with_params(cfg: HrrConfig, params: ParamStore) -> Result<NativeTrainSession> {
        cfg.validate()?;
        validate_native_params(&cfg, &params)?;
        let m = zeros_matching(&params);
        let v = zeros_matching(&params);
        let hyper = TrainHyper::for_task(&cfg.task);
        Ok(NativeTrainSession {
            cfg,
            base: String::new(),
            hyper,
            params,
            m,
            v,
            step: 0,
            scheduler: RowScheduler::Scoped(crate::util::pool::default_budget()),
            dropout: 0.0,
            dropout_seed: 0,
            grad_cache: Vec::new(),
        })
    }

    /// Override the LR schedule (golden fixtures pin their own).
    pub fn with_hyper(mut self, hyper: TrainHyper) -> NativeTrainSession {
        self.hyper = hyper;
        self
    }

    pub fn cfg(&self) -> &HrrConfig {
        &self.cfg
    }

    pub fn hyper(&self) -> &TrainHyper {
        &self.hyper
    }

    /// Optimizer steps taken so far.
    pub fn step(&self) -> u32 {
        self.step
    }

    /// Install the [`RowScheduler`] train/eval batches fan out through.
    pub fn set_scheduler(&mut self, scheduler: RowScheduler) {
        self.scheduler = scheduler;
    }

    pub fn scheduler(&self) -> &RowScheduler {
        &self.scheduler
    }

    /// Enable inverted dropout during [`NativeTrainSession::train_step`]
    /// — on the embedding and both residual branches of every block.
    /// `p` is the drop probability in `[0, 1)`; `seed` drives the mask
    /// streams, independent of the parameter-init seed. Masks depend
    /// only on (seed, step, row, site), so dropped training keeps the
    /// bit-identical-across-schedulers contract; eval, `batch_loss` and
    /// serving never see dropout.
    pub fn set_dropout(&mut self, p: f64, seed: u64) -> Result<()> {
        anyhow::ensure!(
            (0.0..1.0).contains(&p),
            "dropout probability {p} outside [0, 1)"
        );
        self.dropout = p;
        self.dropout_seed = seed;
        Ok(())
    }

    /// The active drop probability (0 = disabled).
    pub fn dropout(&self) -> f64 {
        self.dropout
    }

    fn check_batch(&self, ids: &Tensor, labels: &Tensor) -> Result<(usize, usize)> {
        let shape = ids.shape();
        anyhow::ensure!(shape.len() == 2, "native train expects (B, T) ids, got {shape:?}");
        let (b, t) = (shape[0], shape[1]);
        anyhow::ensure!(b >= 1, "native train needs at least one row");
        anyhow::ensure!(
            t >= 1 && t <= self.cfg.seq_len,
            "sequence length {t} outside 1..={} for this config",
            self.cfg.seq_len
        );
        anyhow::ensure!(
            labels.shape().len() == 1 && labels.shape()[0] == b,
            "labels shape {:?} does not match batch {b}",
            labels.shape()
        );
        let lab = labels.as_i32().context("native train labels dtype")?;
        anyhow::ensure!(
            lab.iter().all(|&l| l >= 0 && (l as usize) < self.cfg.classes),
            "labels must be in 0..{}",
            self.cfg.classes
        );
        Ok((b, t))
    }

    /// Mean loss/accuracy and mean parameter gradients for one batch,
    /// under an explicit scheduler. Gradients come back f64, aligned
    /// with [`param_specs`] order, reduced over rows in ascending order
    /// — bit-identical for every scheduler and worker budget.
    ///
    /// Each row in flight holds one parameter-sized f64 gradient buffer
    /// (the price of the fixed reduction order). No dropout: this is
    /// the exact gradient the finite-difference and golden tests pin.
    pub fn grad_batch(
        &self,
        ids: &Tensor,
        labels: &Tensor,
        scheduler: &RowScheduler,
    ) -> Result<(f64, f64, Vec<Vec<f64>>)> {
        // fresh (empty) cache: standalone calls keep allocating per
        // call; `train_step` threads the session's persistent cache in.
        let mut cache = Vec::new();
        self.grad_batch_cached(ids, labels, scheduler, None, &mut cache)
    }

    /// [`NativeTrainSession::grad_batch`] drawing per-row gradient
    /// buffers from `cache` (zero-filled before reuse) and returning
    /// them there afterwards — byte-for-byte the same results, without
    /// reallocating B parameter-sized buffers per step. `dropout`
    /// carries the step's mask schedule when training with dropout.
    fn grad_batch_cached(
        &self,
        ids: &Tensor,
        labels: &Tensor,
        scheduler: &RowScheduler,
        dropout: Option<DropoutSpec>,
        cache: &mut Vec<RowGrads>,
    ) -> Result<(f64, f64, Vec<Vec<f64>>)> {
        let (b, t) = self.check_batch(ids, labels)?;
        let data = ids.as_i32().context("native train ids dtype")?;
        let lab = labels.as_i32()?;
        let rp = ResolvedParams::resolve(&self.cfg, &self.params)?;

        let mut rows: Vec<RowOut> = (0..b)
            .map(|_| {
                let grads = match cache.pop() {
                    Some(mut g) => {
                        g.clear();
                        g
                    }
                    None => RowGrads::zeros(&self.cfg),
                };
                RowOut { nll: 0.0, correct: false, grads }
            })
            .collect();
        let cfg = &self.cfg;
        let run_rows = |row0: usize, chunk: &mut [RowOut]| {
            let mut tape = Tape::new(cfg);
            let mut gws = GradScratch::new(cfg);
            let mut ws = Workspace::new(cfg);
            let mut logits = vec![0.0f32; cfg.classes];
            for (off, slot) in chunk.iter_mut().enumerate() {
                let r = row0 + off;
                let row_ids = &data[r * t..(r + 1) * t];
                // mask streams fold in the *global* row index, so the
                // chunk partitioning cannot reach the masks
                let ctx = dropout.map(|spec| DropoutCtx::new(spec, r as u64));
                forward_row_tape(cfg, &rp, row_ids, &mut tape, &mut ws, &mut logits, ctx.as_ref());
                let (nll, correct) = backward_row(
                    cfg,
                    &rp,
                    row_ids,
                    lab[r] as usize,
                    &tape,
                    &mut gws,
                    &mut slot.grads,
                    ctx.as_ref(),
                );
                slot.nll = nll;
                slot.correct = correct;
            }
        };
        scatter_rows(scheduler, &mut rows, run_rows)?;

        // fixed-order reduction: rows ascending, f64 — the scheduler
        // cannot influence a single bit of the result
        let mut loss = 0.0f64;
        let mut n_correct = 0usize;
        let mut total: Vec<Vec<f64>> =
            param_specs(&self.cfg).iter().map(|s| vec![0.0; s.elements()]).collect();
        for row in &rows {
            loss += row.nll;
            n_correct += row.correct as usize;
            for (tot, g) in total.iter_mut().zip(&row.grads.tensors) {
                for (a, &gv) in tot.iter_mut().zip(g) {
                    *a += gv;
                }
            }
        }
        let bf = b as f64;
        for tensor in total.iter_mut() {
            for v in tensor.iter_mut() {
                *v /= bf;
            }
        }
        cache.extend(rows.into_iter().map(|r| r.grads));
        Ok((loss / bf, n_correct as f64 / bf, total))
    }

    /// Mean loss/accuracy of one batch, forward only (f64 — the
    /// finite-difference tests need the extra digits). Never dropped:
    /// eval is the deployed network.
    pub fn batch_loss(&self, ids: &Tensor, labels: &Tensor) -> Result<(f64, f64)> {
        let (b, t) = self.check_batch(ids, labels)?;
        let data = ids.as_i32().context("native train ids dtype")?;
        let lab = labels.as_i32()?;
        let rp = ResolvedParams::resolve(&self.cfg, &self.params)?;
        let cfg = &self.cfg;
        let classes = cfg.classes;
        let mut rows: Vec<(f64, bool)> = vec![(0.0, false); b];
        let run_rows = |row0: usize, chunk: &mut [(f64, bool)]| {
            let mut ws = Workspace::new(cfg);
            let mut logits = vec![0.0f32; classes];
            let mut scratch = vec![0.0f64; classes];
            for (off, slot) in chunk.iter_mut().enumerate() {
                let r = row0 + off;
                forward_row(cfg, &rp, &data[r * t..(r + 1) * t], &mut ws, &mut logits);
                *slot = softmax_ce(&logits, lab[r] as usize, &mut scratch);
            }
        };
        scatter_rows(&self.scheduler, &mut rows, run_rows)?;
        let mut loss = 0.0f64;
        let mut n_correct = 0usize;
        for &(nll, correct) in &rows {
            loss += nll;
            n_correct += correct as usize;
        }
        Ok((loss / b as f64, n_correct as f64 / b as f64))
    }

    /// One Adam step (grads from the installed scheduler). LR follows
    /// the exported program's schedule at the *pre-increment* step
    /// counter, exactly like `train_step(…, step)` in model.py. If
    /// dropout is enabled, this is the only path that applies it.
    pub fn train_step(&mut self, ids: &Tensor, labels: &Tensor) -> Result<StepStats> {
        let scheduler = self.scheduler.clone();
        let spec = (self.dropout > 0.0).then(|| DropoutSpec {
            p: self.dropout,
            seed: self.dropout_seed,
            step: self.step as u64,
        });
        // Thread the session's recycled row-gradient buffers through
        // (taken out for the call — `grad_batch_cached` borrows &self).
        let mut cache = std::mem::take(&mut self.grad_cache);
        let result = self.grad_batch_cached(ids, labels, &scheduler, spec, &mut cache);
        self.grad_cache = cache;
        let (loss, acc, grads) = result?;
        self.adam_update(&grads);
        self.step += 1;
        Ok(StepStats { step: self.step, loss: loss as f32, acc: acc as f32 })
    }

    /// Loss/accuracy on a batch without updating parameters.
    pub fn eval_step(&self, ids: &Tensor, labels: &Tensor) -> Result<StepStats> {
        let (loss, acc) = self.batch_loss(ids, labels)?;
        Ok(StepStats { step: self.step, loss: loss as f32, acc: acc as f32 })
    }

    /// In-place Adam with bias correction: f64 math over f32 state,
    /// one f32 round per scalar on the way back (the split the golden
    /// train fixture's numpy reference mirrors).
    fn adam_update(&mut self, grads: &[Vec<f64>]) {
        let lr = self.hyper.lr_at(self.step);
        let t = self.step as f64 + 1.0;
        let bc1 = 1.0 - B1.powf(t);
        let bc2 = 1.0 - B2.powf(t);
        for ((g, p_t), (m_t, v_t)) in grads
            .iter()
            .zip(self.params.tensors.iter_mut())
            .zip(self.m.tensors.iter_mut().zip(self.v.tensors.iter_mut()))
        {
            let p = p_t.as_f32_mut().expect("native params are f32");
            let m = m_t.as_f32_mut().expect("native moments are f32");
            let v = v_t.as_f32_mut().expect("native moments are f32");
            for (((pv, mv), vv), &gv) in
                p.iter_mut().zip(m.iter_mut()).zip(v.iter_mut()).zip(g.iter())
            {
                let m64 = B1 * (*mv as f64) + (1.0 - B1) * gv;
                let v64 = B2 * (*vv as f64) + (1.0 - B2) * gv * gv;
                let p64 = (*pv as f64) - lr * (m64 / bc1) / ((v64 / bc2).sqrt() + ADAM_EPS);
                *mv = m64 as f32;
                *vv = v64 as f32;
                *pv = p64 as f32;
            }
        }
    }

    /// Save parameters as a **versioned artifact**: `HRRART1` manifest
    /// (config hash, per-tensor checksums, provenance) wrapping the
    /// HRRCKPT1 payload — what `Engine::reload` and `POST /admin/reload`
    /// consume. Every checkpoint this session writes verifies on open.
    pub fn save(&self, path: &Path) -> Result<()> {
        self.save_artifact(path, None)
    }

    /// [`NativeTrainSession::save`] with the final eval (loss, accuracy)
    /// recorded as manifest provenance.
    pub fn save_artifact(&self, path: &Path, final_eval: Option<(f32, f32)>) -> Result<()> {
        let provenance = Provenance {
            task: self.cfg.task.clone(),
            base: self.base.clone(),
            step: self.step,
            final_eval,
        };
        Artifact::write(path, &self.cfg, &self.params, provenance)?;
        Ok(())
    }

    /// Restore parameters from a checkpoint — a versioned `HRRART1`
    /// artifact (manifest + checksums fully verified; corruption
    /// surfaces as a typed [`crate::model::ArtifactError`]) or a legacy
    /// bare HRRCKPT1 payload. The whole optimizer state resets with
    /// them: Adam moments to zero **and** the step counter to 0, so
    /// bias correction and the LR schedule restart consistently with
    /// the fresh moments (stale `step` would make the first
    /// post-restore update ~3× too large and pin LR at the decayed
    /// floor).
    pub fn restore(&mut self, path: &Path) -> Result<()> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("read checkpoint {}", path.display()))?;
        let loaded = if Artifact::sniff(&bytes) {
            Artifact::open_bytes(&bytes)
                .with_context(|| format!("verify artifact {}", path.display()))?
                .params
        } else {
            // legacy bare HRRCKPT1 checkpoint (pre-artifact saves)
            ParamStore::read_from(&mut std::io::Cursor::new(&bytes[..]))
                .with_context(|| format!("parse checkpoint {}", path.display()))?
        };
        validate_native_params(&self.cfg, &loaded)?;
        self.params = loaded;
        self.m = zeros_matching(&self.params);
        self.v = zeros_matching(&self.params);
        self.step = 0;
        Ok(())
    }
}

/// A zeroed store with the same names/shapes (Adam moments start at 0).
fn zeros_matching(store: &ParamStore) -> ParamStore {
    ParamStore {
        names: store.names.clone(),
        tensors: store.tensors.iter().map(|t| Tensor::zeros(t.dtype(), t.shape())).collect(),
    }
}

impl NativeTrainSession {
    /// The current parameters (the live training state, not a copy).
    pub fn params(&self) -> &ParamStore {
        &self.params
    }
}

impl Session for NativeTrainSession {
    fn batch(&self) -> usize {
        self.cfg.batch
    }

    fn seq_len(&self) -> usize {
        self.cfg.seq_len
    }

    fn param_scalars(&self) -> usize {
        self.params.total_scalars()
    }
}

impl Trainable for NativeTrainSession {
    fn train_step(&mut self, ids: &Tensor, labels: &Tensor) -> Result<StepStats> {
        NativeTrainSession::train_step(self, ids, labels)
    }

    fn eval_step(&self, ids: &Tensor, labels: &Tensor) -> Result<StepStats> {
        NativeTrainSession::eval_step(self, ids, labels)
    }

    fn has_eval(&self) -> bool {
        true
    }

    fn save(&self, path: &Path) -> Result<()> {
        NativeTrainSession::save(self, path)
    }

    fn restore(&mut self, path: &Path) -> Result<()> {
        NativeTrainSession::restore(self, path)
    }

    fn save_artifact(&self, path: &Path, final_eval: Option<(f32, f32)>) -> Result<()> {
        NativeTrainSession::save_artifact(self, path, final_eval)
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::hrr::arch::Arch;
    use crate::hrr::{NativeSession, PAD_ID};
    use crate::util::pool::WorkerPool;

    /// pow2 head dim (radix-2 FFT path), fixed sinusoid positions.
    fn tiny_cfg() -> HrrConfig {
        HrrConfig {
            arch: Arch::Hrrformer,
            task: "test".into(),
            vocab: 9,
            seq_len: 6,
            batch: 2,
            embed: 8,
            mlp_dim: 10,
            heads: 2,
            layers: 2,
            classes: 3,
            learned_pos: false,
        }
    }

    /// non-pow2 head dim (naive-DFT fallback), learned positions.
    fn naive_cfg() -> HrrConfig {
        HrrConfig {
            arch: Arch::Hrrformer,
            task: "test".into(),
            vocab: 9,
            seq_len: 5,
            batch: 2,
            embed: 12,
            mlp_dim: 8,
            heads: 2,
            layers: 1,
            classes: 3,
            learned_pos: true,
        }
    }

    /// The same skeleton with the HGConv mixer swapped in.
    fn hg(cfg: HrrConfig) -> HrrConfig {
        HrrConfig { arch: Arch::HgConv, ..cfg }
    }

    fn tiny_batch(t: usize) -> (Tensor, Tensor) {
        let mut flat: Vec<i32> = (0..2 * t).map(|i| 1 + (i as i32 * 5 + 3) % 7).collect();
        // PAD tail on the second row exercises the mask
        let tail = t / 3;
        for v in flat[2 * t - tail..].iter_mut() {
            *v = PAD_ID;
        }
        (Tensor::i32(vec![2, t], flat), Tensor::i32(vec![2], vec![1, 0]))
    }

    #[test]
    fn lr_schedule_decays_and_floors() {
        let h = TrainHyper { lr: 1e-3, lr_min: 1e-5, decay_rate: 0.5, steps_per_epoch: 10.0 };
        assert_eq!(h.lr_at(0), 1e-3);
        assert!((h.lr_at(10) - 5e-4).abs() < 1e-12);
        assert!(h.lr_at(5) < h.lr_at(0) && h.lr_at(5) > h.lr_at(10));
        assert_eq!(h.lr_at(10_000), 1e-5, "schedule must floor at lr_min");
    }

    #[test]
    fn tape_forward_matches_predict_forward_bitwise() {
        for cfg in [tiny_cfg(), naive_cfg(), hg(tiny_cfg()), hg(naive_cfg())] {
            let params = init_native_params(&cfg, 11);
            let rp = ResolvedParams::resolve(&cfg, &params).unwrap();
            let (ids, _) = tiny_batch(cfg.seq_len);
            let data = ids.as_i32().unwrap();
            let t = cfg.seq_len;
            let mut tape = Tape::new(&cfg);
            let mut tape_ws = Workspace::new(&cfg);
            let mut ws = Workspace::new(&cfg);
            let mut got = vec![0.0f32; cfg.classes];
            let mut want = vec![0.0f32; cfg.classes];
            for r in 0..2 {
                let row = &data[r * t..(r + 1) * t];
                forward_row_tape(&cfg, &rp, row, &mut tape, &mut tape_ws, &mut got, None);
                forward_row(&cfg, &rp, row, &mut ws, &mut want);
                assert_eq!(tape.logits, want, "taped forward must be bit-identical");
                assert_eq!(got, want, "taped forward's own logits must match too");
            }
        }
    }

    /// Central-difference check of `∂L/∂θ_j` against `batch_loss` for
    /// the largest-gradient scalars of every parameter tensor — for
    /// both architectures.
    ///
    /// The f32 forward has a deterministic rounding floor, so each probe
    /// needs signal well above it: h = 2e-3 per scalar (realized f32
    /// perturbation as the divisor) and probes whose predicted |ΔL|
    /// falls under 1e-4 are skipped. At these settings the residual is
    /// pure O(h²) truncation, measured ≤ 3.5e-4 against a numpy
    /// transcription — the 1e-3 gate holds with margin. (The per-tensor
    /// *full-gradient* pin lives in golden_train.rs against the
    /// fixture's f64 reference gradients.)
    #[test]
    fn finite_difference_checks_every_parameter_group() {
        for cfg in [tiny_cfg(), naive_cfg(), hg(tiny_cfg()), hg(naive_cfg())] {
            let sess = NativeTrainSession::from_config(cfg.clone(), 7).unwrap();
            let (ids, labels) = tiny_batch(cfg.seq_len);
            let (_, _, grads) =
                sess.grad_batch(&ids, &labels, &RowScheduler::Sequential).unwrap();
            let specs = param_specs(&cfg);
            let mut probes = 0usize;
            for (gi, g) in grads.iter().enumerate() {
                assert!(
                    g.iter().all(|v| v.is_finite()),
                    "{}: non-finite gradient",
                    specs[gi].name
                );
                // top-3 scalars by |g|
                let mut order: Vec<usize> = (0..g.len()).collect();
                order.sort_by(|&a, &b| g[b].abs().partial_cmp(&g[a].abs()).unwrap());
                for &j in order.iter().take(3) {
                    let old = sess.params().tensors[gi].as_f32().unwrap()[j];
                    let pv = (old as f64 + 2e-3) as f32;
                    let mv = (old as f64 - 2e-3) as f32;
                    let dj = pv as f64 - mv as f64;
                    if (dj * g[j]).abs() < 1e-4 {
                        continue; // predicted ΔL under the rounding floor
                    }
                    let mut plus = sess.params().clone();
                    plus.tensors[gi].as_f32_mut().unwrap()[j] = pv;
                    let mut minus = sess.params().clone();
                    minus.tensors[gi].as_f32_mut().unwrap()[j] = mv;
                    let sp = NativeTrainSession::with_params(cfg.clone(), plus).unwrap();
                    let sm = NativeTrainSession::with_params(cfg.clone(), minus).unwrap();
                    let (lp, _) = sp.batch_loss(&ids, &labels).unwrap();
                    let (lm, _) = sm.batch_loss(&ids, &labels).unwrap();
                    let num = (lp - lm) / dj;
                    let err = (num - g[j]).abs() / num.abs().max(g[j].abs()).max(1e-12);
                    assert!(
                        err <= 1e-3,
                        "{} {}[{j}]: analytic {:.6e} vs central difference {num:.6e} \
                         (rel err {err:.2e})",
                        cfg.arch,
                        specs[gi].name,
                        g[j]
                    );
                    probes += 1;
                }
            }
            // nearly every tensor contributes probes above the floor
            // (the HGConv skeleton has smaller taps tensors, so allow
            // a lower count there)
            let floor = match cfg.arch {
                Arch::Hrrformer => 2 * specs.len(),
                Arch::HgConv => specs.len(),
            };
            assert!(probes >= floor, "{}: only {probes} probes ran", cfg.arch);
        }
    }

    #[test]
    fn gradients_bit_identical_across_schedulers_and_budgets() {
        for cfg in [tiny_cfg(), hg(tiny_cfg())] {
            let sess = NativeTrainSession::from_config(cfg.clone(), 3).unwrap();
            let (ids, labels) = tiny_batch(cfg.seq_len);
            let (l0, a0, g0) =
                sess.grad_batch(&ids, &labels, &RowScheduler::Sequential).unwrap();
            let pool1 = Arc::new(WorkerPool::new(1));
            let pool3 = Arc::new(WorkerPool::new(3));
            for sched in [
                RowScheduler::Scoped(2),
                RowScheduler::Scoped(5),
                RowScheduler::Pool(pool1.clone()),
                RowScheduler::Pool(pool3.clone()),
            ] {
                let (l, a, g) = sess.grad_batch(&ids, &labels, &sched).unwrap();
                assert_eq!(l.to_bits(), l0.to_bits(), "loss drifted under {sched:?}");
                assert_eq!(a, a0);
                for (ta, tb) in g0.iter().zip(&g) {
                    for (&x, &y) in ta.iter().zip(tb) {
                        assert_eq!(x.to_bits(), y.to_bits(), "gradient drifted under {sched:?}");
                    }
                }
            }
        }
    }

    #[test]
    fn train_step_trajectory_is_scheduler_independent() {
        let cfg = tiny_cfg();
        let (ids, labels) = tiny_batch(cfg.seq_len);
        let mut a = NativeTrainSession::from_config(cfg.clone(), 5).unwrap();
        a.set_scheduler(RowScheduler::Sequential);
        let mut b = NativeTrainSession::from_config(cfg, 5).unwrap();
        b.set_scheduler(RowScheduler::Pool(Arc::new(WorkerPool::new(2))));
        for _ in 0..3 {
            let sa = a.train_step(&ids, &labels).unwrap();
            let sb = b.train_step(&ids, &labels).unwrap();
            assert_eq!(sa.loss.to_bits(), sb.loss.to_bits());
        }
        assert_eq!(a.params().tensors, b.params().tensors, "params must stay bit-identical");
    }

    /// The scheduler contract survives dropout: masks derive from
    /// (seed, step, row, site), never from the partitioning, so a
    /// dropped trajectory is bit-identical under every scheduler too.
    #[test]
    fn dropout_trajectory_is_scheduler_independent() {
        let cfg = tiny_cfg();
        let (ids, labels) = tiny_batch(cfg.seq_len);
        let mut a = NativeTrainSession::from_config(cfg.clone(), 5).unwrap();
        a.set_dropout(0.25, 42).unwrap();
        a.set_scheduler(RowScheduler::Sequential);
        let mut b = NativeTrainSession::from_config(cfg, 5).unwrap();
        b.set_dropout(0.25, 42).unwrap();
        b.set_scheduler(RowScheduler::Pool(Arc::new(WorkerPool::new(2))));
        for _ in 0..3 {
            let sa = a.train_step(&ids, &labels).unwrap();
            let sb = b.train_step(&ids, &labels).unwrap();
            assert_eq!(sa.loss.to_bits(), sb.loss.to_bits(), "dropped loss drifted");
        }
        assert_eq!(a.params().tensors, b.params().tensors, "dropped params drifted");
    }

    #[test]
    fn dropout_masks_follow_the_seed() {
        let cfg = tiny_cfg();
        let (ids, labels) = tiny_batch(cfg.seq_len);
        let mut mk = |seed: u64| {
            let mut s = NativeTrainSession::from_config(cfg.clone(), 5).unwrap();
            s.set_dropout(0.5, seed).unwrap();
            s.set_scheduler(RowScheduler::Sequential);
            s.train_step(&ids, &labels).unwrap().loss
        };
        let la = mk(1);
        let lb = mk(1);
        let lc = mk(2);
        assert_eq!(la.to_bits(), lb.to_bits(), "same mask seed must replay exactly");
        assert_ne!(la.to_bits(), lc.to_bits(), "different mask seeds must differ");
        // and dropout actually changes the step relative to no dropout
        let clean = NativeTrainSession::from_config(cfg, 5)
            .map(|mut s| {
                s.set_scheduler(RowScheduler::Sequential);
                s.train_step(&ids, &labels).unwrap().loss
            })
            .unwrap();
        assert_ne!(la.to_bits(), clean.to_bits(), "p=0.5 must perturb the loss");
    }

    #[test]
    fn eval_paths_never_see_dropout() {
        let cfg = tiny_cfg();
        let (ids, labels) = tiny_batch(cfg.seq_len);
        let mut sess = NativeTrainSession::from_config(cfg, 3).unwrap();
        let (l0, a0) = sess.batch_loss(&ids, &labels).unwrap();
        sess.set_dropout(0.9, 7).unwrap();
        let (l1, a1) = sess.batch_loss(&ids, &labels).unwrap();
        assert_eq!(l0.to_bits(), l1.to_bits(), "batch_loss must ignore dropout");
        assert_eq!(a0, a1);
        let (_, _, g0) = sess.grad_batch(&ids, &labels, &RowScheduler::Sequential).unwrap();
        assert!(
            g0.iter().flatten().all(|v| v.is_finite()),
            "grad_batch is the undropped exact gradient"
        );
    }

    #[test]
    fn dropout_probability_is_validated() {
        let mut sess = NativeTrainSession::from_config(tiny_cfg(), 1).unwrap();
        assert!(sess.set_dropout(1.0, 0).is_err(), "p=1 would zero the network");
        assert!(sess.set_dropout(-0.1, 0).is_err());
        assert!(sess.set_dropout(0.999, 0).is_ok());
        assert!(sess.set_dropout(0.0, 0).is_ok(), "p=0 disables dropout");
        assert_eq!(sess.dropout(), 0.0);
    }

    /// Recycled row-gradient buffers must be invisible in the numbers:
    /// a session reusing its cache across steps walks the exact same
    /// trajectory as stepping through fresh-allocating `grad_batch`
    /// calls by hand.
    #[test]
    fn grad_buffer_recycling_keeps_trajectory_bit_identical() {
        let cfg = tiny_cfg();
        let (ids, labels) = tiny_batch(cfg.seq_len);
        let mut cached = NativeTrainSession::from_config(cfg.clone(), 11).unwrap();
        cached.set_scheduler(RowScheduler::Sequential);
        let mut manual = NativeTrainSession::from_config(cfg, 11).unwrap();
        for _ in 0..3 {
            let sa = cached.train_step(&ids, &labels).unwrap();
            // fresh buffers every call (empty cache inside grad_batch)
            let (loss, acc, grads) =
                manual.grad_batch(&ids, &labels, &RowScheduler::Sequential).unwrap();
            manual.adam_update(&grads);
            manual.step += 1;
            assert_eq!(sa.loss.to_bits(), (loss as f32).to_bits());
            assert_eq!(sa.acc.to_bits(), (acc as f32).to_bits());
        }
        assert!(!cached.grad_cache.is_empty(), "train_step must retain buffers for reuse");
        assert_eq!(cached.params().tensors, manual.params().tensors);
    }

    #[test]
    fn loss_decreases_over_20_steps_on_a_fixed_batch() {
        use crate::data::{batch::BatchStream, by_task, Split};
        let cfg = HrrConfig::from_base("listops_hrrformer_small_T16_B4").unwrap();
        let ds = by_task("listops", 16).unwrap();
        let batch = BatchStream::new(ds.as_ref(), Split::Train, 1, 4, 16).next_batch();
        let mut sess = NativeTrainSession::from_config(cfg, 0).unwrap();
        let first = sess.train_step(&batch.ids, &batch.labels).unwrap().loss;
        let mut last = first;
        for _ in 0..19 {
            last = sess.train_step(&batch.ids, &batch.labels).unwrap().loss;
        }
        assert!(last.is_finite() && first.is_finite());
        assert!(
            last < first,
            "overfitting one batch must reduce the loss: {first} -> {last}"
        );
    }

    /// The same overfitting smoke for the second architecture — HGConv
    /// trains end-to-end through the identical session machinery.
    #[test]
    fn hgconv_loss_decreases_over_20_steps_on_a_fixed_batch() {
        use crate::data::{batch::BatchStream, by_task, Split};
        let cfg = HrrConfig::from_base("listops_hgconv_small_T16_B4").unwrap();
        assert_eq!(cfg.arch, Arch::HgConv);
        let ds = by_task("listops", 16).unwrap();
        let batch = BatchStream::new(ds.as_ref(), Split::Train, 1, 4, 16).next_batch();
        let mut sess = NativeTrainSession::from_config(cfg, 0).unwrap();
        let first = sess.train_step(&batch.ids, &batch.labels).unwrap().loss;
        let mut last = first;
        for _ in 0..19 {
            last = sess.train_step(&batch.ids, &batch.labels).unwrap().loss;
        }
        assert!(last.is_finite() && first.is_finite());
        assert!(
            last < first,
            "overfitting one batch must reduce the loss: {first} -> {last}"
        );
    }

    #[test]
    fn all_pad_rows_train_without_nans() {
        for cfg in [tiny_cfg(), hg(tiny_cfg())] {
            let mut sess = NativeTrainSession::from_config(cfg.clone(), 2).unwrap();
            let mut flat = vec![0i32; 2 * cfg.seq_len];
            for v in flat[..cfg.seq_len].iter_mut() {
                *v = 3;
            }
            let ids = Tensor::i32(vec![2, cfg.seq_len], flat); // second row all-PAD
            let labels = Tensor::i32(vec![2], vec![0, 1]);
            let stats = sess.train_step(&ids, &labels).unwrap();
            assert!(stats.loss.is_finite());
            for t in &sess.params().tensors {
                assert!(t.as_f32().unwrap().iter().all(|v| v.is_finite()));
            }
        }
    }

    #[test]
    fn rejects_bad_labels_and_shapes() {
        let cfg = tiny_cfg();
        let sess = NativeTrainSession::from_config(cfg.clone(), 1).unwrap();
        let (ids, _) = tiny_batch(cfg.seq_len);
        let bad = Tensor::i32(vec![2], vec![0, 99]);
        assert!(sess.batch_loss(&ids, &bad).is_err(), "out-of-range label must error");
        let wrong_arity = Tensor::i32(vec![3], vec![0, 1, 0]);
        assert!(sess.batch_loss(&ids, &wrong_arity).is_err());
    }

    #[test]
    fn checkpoint_roundtrips_into_serving_session() {
        let cfg = tiny_cfg();
        let (ids, labels) = tiny_batch(cfg.seq_len);
        let mut sess = NativeTrainSession::from_config(cfg.clone(), 9).unwrap();
        for _ in 0..2 {
            sess.train_step(&ids, &labels).unwrap();
        }
        let dir = std::env::temp_dir().join("hrrformer_native_train_ckpt");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("native.ckpt");
        sess.save(&path).unwrap();
        // save writes a verified artifact: manifest + checksums wrap the
        // payload, and the serving session accepts the parameters
        let art = crate::model::Artifact::open(&path).unwrap();
        assert_eq!(art.manifest.provenance.step, 2);
        let serve = NativeSession::with_params(cfg.clone(), art.params).unwrap();
        let logits = serve.predict(&ids).unwrap();
        assert!(logits.as_f32().unwrap().iter().all(|v| v.is_finite()));
        // restore resets the optimizer but keeps the parameters
        let trained = sess.params().tensors.clone();
        let mut fresh = NativeTrainSession::from_config(cfg.clone(), 1).unwrap();
        fresh.restore(&path).unwrap();
        assert_eq!(fresh.params().tensors, trained);
        // optimizer state (incl. the step counter driving bias
        // correction + LR) restarts on restore
        sess.restore(&path).unwrap();
        assert_eq!(sess.step(), 0, "restore must reset the optimizer step");
        // legacy bare HRRCKPT1 checkpoints still restore
        let legacy = dir.join("native_legacy.ckpt");
        sess.params().save(&legacy).unwrap();
        let mut old = NativeTrainSession::from_config(cfg, 4).unwrap();
        old.restore(&legacy).unwrap();
        assert_eq!(old.params().tensors, trained);
        // a flipped payload byte must be caught by the checksums
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        let err = sess.restore(&path).unwrap_err();
        assert!(
            format!("{err:#}").contains("checksum"),
            "corruption must surface as a checksum error, got: {err:#}"
        );
    }
}
