//! Model state management: parameter stores, checkpoints, and the
//! train/predict/weights sessions that drive the AOT programs.

pub mod params;
pub mod session;

pub use params::ParamStore;
pub use session::{PredictSession, StepStats, TrainSession, WeightsSession};
