//! Per-bucket executor thread: builds and owns one predict session,
//! batches its queue with deadline-aware flushing, and executes.
//!
//! The session is built *inside* the executor thread and held as a
//! `Box<dyn Predictor>` — either a compiled `PredictSession` (the xla
//! crate's PJRT handles are `!Send` and must never cross a thread
//! boundary) or the artifact-free `NativeSession`; only plain data
//! (token ids, logits, errors) moves over the channels. Each bucket gets
//! its own executor, so a slow T=1024 batch cannot head-of-line-block
//! T=256 traffic — the routing thread stays free to feed every other
//! bucket in parallel.

use std::path::PathBuf;
use std::sync::mpsc::{Receiver, RecvTimeoutError, SyncSender, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::batcher::{BatchPolicy, BatchQueue, Pending};
use crate::engine::error::EngineError;
use crate::engine::{Backend, EngineStats, ExecSpan, InferReply};
use crate::hrr::{HrrConfig, NativeSession, ParamSlot, RowScheduler};
use crate::model::{ParamStore, PredictSession, Predictor, Session};
use crate::runtime::{Manifest, Runtime, Tensor};
use crate::util::pool::WorkerPool;

/// A routed request, as handed from the routing thread to an executor.
pub(crate) struct Job {
    pub ids: Vec<i32>,
    /// Set by the router when the request is longer than every bucket and
    /// executes truncated to the largest T (paper protocol for EMBER).
    pub truncated: bool,
    /// Submission time at the client — latency covers routing + queueing
    /// + execution.
    pub submitted: Instant,
    /// Per-request latency budget (`submit_deadline`), mapped onto the
    /// batcher's `max_wait` via [`effective_enqueue`].
    pub deadline: Option<Duration>,
    /// Live bucket queue-depth gauge; decrements when the job is
    /// dropped (i.e. after its reply is sent, on every path).
    pub depth: Option<crate::engine::DepthGuard>,
    pub reply: SyncSender<Result<InferReply, EngineError>>,
}

/// Map a per-request deadline onto the batcher's single `max_wait` by
/// backdating the enqueue instant: the flush deadline the queue computes
/// is `enqueued + max_wait`, so returning `submitted - (max_wait - d)`
/// makes it land at `submitted + d`. Deadlines looser than the policy
/// change nothing — the engine never waits longer than its own
/// `max_wait` anyway.
pub(crate) fn effective_enqueue(
    submitted: Instant,
    deadline: Option<Duration>,
    max_wait: Duration,
) -> Instant {
    match deadline {
        Some(d) if d < max_wait => submitted.checked_sub(max_wait - d).unwrap_or(submitted),
        _ => submitted,
    }
}

pub(crate) enum ExecMsg {
    Job(Job),
    /// Drain the queue, reply to everything still pending, then exit.
    Shutdown,
}

/// Everything an executor needs to build its thread-local session.
pub(crate) struct ExecutorConfig {
    pub base: String,
    pub backend: Backend,
    /// Present for [`Backend::Artifact`]; the native backend needs none.
    pub manifest_dir: Option<PathBuf>,
    pub seed: u32,
    /// Trained parameters (None = seed-initialized). Artifact backend
    /// only — native buckets carry their weights in `slot`.
    pub params: Option<ParamStore>,
    /// The bucket's versioned weight slot (native backend): owned by
    /// the engine's [`crate::engine::ReloadHub`], pinned by the session
    /// once per batch, hot-swapped by `Engine::reload`.
    pub slot: Option<Arc<ParamSlot>>,
    pub policy: BatchPolicy,
    /// The engine's shared worker pool (native backend): installed as
    /// the session's row scheduler, so every bucket's predict rows run
    /// on the same fixed thread set instead of per-batch scoped spawns.
    pub pool: Option<Arc<WorkerPool>>,
}

/// Idle wake-up period when the queue is empty (no deadline to sleep to).
const IDLE_TICK: Duration = Duration::from_millis(50);

/// Thread body: build the session (signalling readiness), then loop.
pub(crate) fn run_executor(
    mut cfg: ExecutorConfig,
    rx: Receiver<ExecMsg>,
    ready: SyncSender<Result<()>>,
    stats: Arc<EngineStats>,
) {
    let sess = match build_session(&mut cfg) {
        Ok(s) => {
            let _ = ready.send(Ok(()));
            s
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    executor_loop(sess.as_ref(), rx, cfg.policy, &stats);
}

/// Build the bucket's session for the configured backend. Either way the
/// result lives and dies on this thread.
fn build_session(cfg: &mut ExecutorConfig) -> Result<Box<dyn Predictor>> {
    // take() the trained params — no transient copy of multi-MB weights
    let params = cfg.params.take();
    match cfg.backend {
        Backend::Artifact => {
            let dir = cfg
                .manifest_dir
                .as_ref()
                .context("artifact backend requires a manifest directory")?;
            let manifest = Manifest::load(dir)?;
            let rt = Runtime::cpu().context("executor PJRT runtime")?;
            let sess = match params {
                Some(p) => PredictSession::with_params(&rt, &manifest, &cfg.base, p),
                None => PredictSession::create(&rt, &manifest, &cfg.base, cfg.seed),
            }
            .with_context(|| format!("compile bucket '{}'", cfg.base))?;
            Ok(Box::new(sess))
        }
        Backend::Native => {
            // The builder seeded the slot (explicit params or seed
            // init); serving from it keeps the bucket hot-reloadable.
            let slot = cfg
                .slot
                .take()
                .context("native executor requires a versioned param slot")?;
            let mut sess = NativeSession::with_slot(HrrConfig::from_base(&cfg.base)?, slot)
                .with_context(|| format!("build native bucket '{}'", cfg.base))?;
            if let Some(pool) = cfg.pool.take() {
                sess.set_scheduler(RowScheduler::Pool(pool));
            }
            Ok(Box::new(sess))
        }
    }
}

fn executor_loop(
    sess: &dyn Predictor,
    rx: Receiver<ExecMsg>,
    policy: BatchPolicy,
    stats: &Arc<EngineStats>,
) {
    // Clamp the flush size to this bucket's fixed batch capacity once,
    // up front: a `BatchPolicy { max_batch > B }` would otherwise flush
    // more rows than the (B, T) tensor holds and `execute_batch` would
    // pack out of bounds — panicking the executor thread in release and
    // wedging the bucket. Oversized policies now just batch at B.
    let policy = policy.clamped_to(sess.batch());
    let mut queue: BatchQueue<Job> = BatchQueue::new(policy);
    let mut draining = false;
    // Monotone per-bucket reply sequence — lets clients (and tests)
    // observe FIFO ordering without cross-request channels.
    let mut seq = 0u64;

    loop {
        // Sleep until the oldest request's deadline (or a short tick).
        let now = Instant::now();
        let wait = queue.time_to_deadline(now).unwrap_or(IDLE_TICK);
        match rx.recv_timeout(wait) {
            // Deadline from client submission, not queue arrival: time a
            // request spent in the admission/bucket channels counts
            // toward max_wait, so under backpressure a pre-aged job
            // flushes immediately instead of waiting a fresh deadline.
            Ok(ExecMsg::Job(job)) => {
                let enqueued = effective_enqueue(job.submitted, job.deadline, policy.max_wait);
                queue.push_at(job, enqueued);
                // Greedily drain whatever else already sits in the
                // channel before deciding to flush. Submission-time
                // deadlines mean a backpressured job can arrive
                // pre-aged; flushing on it alone would collapse
                // batching to size-1 exactly when the engine is
                // overloaded and coalescing matters most. The channel
                // is bounded (queue_depth), so this loop is too.
                loop {
                    match rx.try_recv() {
                        Ok(ExecMsg::Job(job)) => {
                            let enqueued = effective_enqueue(
                                job.submitted,
                                job.deadline,
                                policy.max_wait,
                            );
                            queue.push_at(job, enqueued);
                        }
                        Ok(ExecMsg::Shutdown) | Err(TryRecvError::Disconnected) => {
                            draining = true;
                            break;
                        }
                        Err(TryRecvError::Empty) => break,
                    }
                }
            }
            Ok(ExecMsg::Shutdown) | Err(RecvTimeoutError::Disconnected) => draining = true,
            Err(RecvTimeoutError::Timeout) => {}
        }

        let now = Instant::now();
        while let Some(batch) = queue.maybe_flush(now, draining) {
            execute_batch(sess, batch, stats, &mut seq);
        }

        if draining && queue.is_empty() {
            return;
        }
    }
}

/// Pack a flushed batch into the fixed (B, T) tensor, execute, and fan
/// replies out per request. Any failure — execution *or* logits decoding
/// (dtype/shape mismatch) — is propagated as `EngineError::Predict` to
/// every request in the batch; a bad batch never degrades into silent
/// `label=0` / empty-logits replies.
fn execute_batch(
    sess: &dyn Predictor,
    batch: Vec<Pending<Job>>,
    stats: &Arc<EngineStats>,
    seq: &mut u64,
) {
    let t = sess.seq_len();
    let cap = sess.batch();
    // n ≤ cap always: `executor_loop` clamps the batch policy to the
    // session's capacity before the queue exists.
    let n = batch.len();
    // Pack into the fixed (cap, T) tensor; unused rows stay PAD.
    let mut ids = vec![0i32; cap * t];
    for (row, p) in batch.iter().enumerate() {
        let src = &p.payload.ids;
        let len = src.len().min(t);
        ids[row * t..row * t + len].copy_from_slice(&src[..len]);
    }
    let tensor = Tensor::i32(vec![cap, t], ids);

    let start = Instant::now();
    // predict_versioned pins one weight version for the whole batch —
    // a concurrent reload flips the slot for the *next* batch, never
    // this one — and reports which version produced the logits.
    let result = sess
        .predict_versioned(&tensor)
        .map_err(|e| format!("{e:#}"))
        .and_then(|(l, v)| decode(&l, cap).map(|d| (d, v)));
    let end = Instant::now();
    stats.record_span(ExecSpan { bucket_t: t, batch_size: n, start, end });

    match result {
        Ok(((data, classes, preds), model_version)) => {
            for (row, p) in batch.into_iter().enumerate() {
                let latency = end.duration_since(p.payload.submitted);
                stats.latency.record(latency);
                stats.throughput.add(1);
                let reply = InferReply {
                    label: preds[row],
                    logits: data[row * classes..(row + 1) * classes].to_vec(),
                    latency,
                    bucket_t: t,
                    batch_size: n,
                    truncated: p.payload.truncated,
                    seq: *seq,
                    model_version,
                };
                *seq += 1;
                let _ = p.payload.reply.send(Ok(reply));
            }
        }
        Err(msg) => {
            for p in batch {
                let _ = p.payload.reply.send(Err(EngineError::Predict(msg.clone())));
            }
        }
    }
}

/// Validate and decompose the logits tensor: row-major (cap, classes)
/// f32 data plus per-row argmax. Errors instead of defaulting so dtype
/// or shape drift in the artifacts surfaces as a request failure.
fn decode(logits: &Tensor, cap: usize) -> Result<(Vec<f32>, usize, Vec<usize>), String> {
    let data =
        logits.as_f32().map_err(|e| format!("logits dtype: {e:#}"))?.to_vec();
    let classes = logits.shape().last().copied().unwrap_or(0);
    if classes == 0 || data.len() != cap * classes {
        return Err(format!(
            "logits shape {:?} inconsistent with batch capacity {cap}",
            logits.shape()
        ));
    }
    let preds = logits.argmax_last().map_err(|e| format!("logits argmax: {e:#}"))?;
    if preds.len() != cap {
        return Err(format!("argmax produced {} rows, expected {cap}", preds.len()));
    }
    Ok((data, classes, preds))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deadline_backdates_enqueue_to_land_flush_at_submitted_plus_deadline() {
        let wait = Duration::from_millis(10);
        let now = Instant::now();

        // Tighter deadline: enqueue is backdated so enqueued + max_wait
        // == submitted + deadline.
        let e = effective_enqueue(now, Some(Duration::from_millis(3)), wait);
        assert_eq!(e + wait, now + Duration::from_millis(3));

        // Looser-than-policy and absent deadlines change nothing.
        let e = effective_enqueue(now, Some(Duration::from_millis(50)), wait);
        assert_eq!(e, now);
        let e = effective_enqueue(now, None, wait);
        assert_eq!(e, now);

        // Exactly-equal deadline is the identity mapping too.
        let e = effective_enqueue(now, Some(wait), wait);
        assert_eq!(e, now);
    }
}
