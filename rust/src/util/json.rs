//! Minimal JSON parser/serializer (the offline build has no serde).
//!
//! Supports the full JSON grammar minus exotic number forms; good enough
//! for `artifacts/manifest.json` and the metrics emitters. Strings are
//! unescaped for the common escapes (`\" \\ \/ \n \t \r \b \f \uXXXX`).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        if self.pos + 4 > self.b.len() {
                            return Err(self.err("bad \\u escape"));
                        }
                        let hex = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
                            .map_err(|_| self.err("bad \\u escape"))?;
                        let cp = u32::from_str_radix(hex, 16)
                            .map_err(|_| self.err("bad \\u escape"))?;
                        self.pos += 4;
                        out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // multi-byte UTF-8: copy the remaining continuation bytes
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.pos - 1;
                    self.pos = (start + len).min(self.b.len());
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_json(self, &mut s);
        f.write_str(&s)
    }
}

pub fn write_json(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if !n.is_finite() {
                // JSON has no NaN/inf literal: a bare `NaN` token makes
                // the whole document unparseable, silently corrupting
                // trajectory files. Emit the one lossless stand-in.
                out.push_str("null");
            } else if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{}", n));
            }
        }
        Json::Str(s) => escape(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, v) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(v, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, v)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape(k, out);
                out.push(':');
                write_json(v, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_doc() {
        let doc = r#"{"programs": {"a_b": {"file": "a.hlo.txt", "seq_len": 1024,
            "inputs": [{"name":"seed","shape":[],"dtype":"u32"}], "ok": true, "x": null}}}"#;
        let j = Json::parse(doc).unwrap();
        let prog = j.get("programs").unwrap().get("a_b").unwrap();
        assert_eq!(prog.get("file").unwrap().as_str(), Some("a.hlo.txt"));
        assert_eq!(prog.get("seq_len").unwrap().as_usize(), Some(1024));
        let ins = prog.get("inputs").unwrap().as_arr().unwrap();
        assert_eq!(ins[0].get("dtype").unwrap().as_str(), Some("u32"));
        assert_eq!(prog.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(prog.get("x"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let doc = r#"{"a":[1,2.5,-3],"b":"hi\nthere","c":{"d":false}}"#;
        let j = Json::parse(doc).unwrap();
        let s = j.to_string();
        assert_eq!(Json::parse(&s).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{bad}").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{} extra").is_err());
    }

    #[test]
    fn non_finite_numbers_serialize_as_null_not_invalid_json() {
        for v in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            assert_eq!(Json::Num(v).to_string(), "null");
        }
        // a document carrying a degenerate number must stay parseable
        let mut m = BTreeMap::new();
        m.insert("speedup".to_string(), Json::Num(f64::NAN));
        m.insert("ok".to_string(), Json::Num(2.5));
        let doc = Json::Obj(m).to_string();
        let parsed = Json::parse(&doc).expect("serializer must never emit invalid JSON");
        assert_eq!(parsed.get("speedup"), Some(&Json::Null));
        assert_eq!(parsed.get("ok").and_then(Json::as_f64), Some(2.5));
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""café →""#).unwrap();
        assert_eq!(j.as_str(), Some("café →"));
    }
}
