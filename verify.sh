#!/usr/bin/env bash
# verify.sh — the tier-1 gate, runnable locally and in CI.
#
#   ./verify.sh          # build + test + fmt + clippy
#   ./verify.sh --fast   # build + test only
#
# Tests of the PJRT runtime/training path need AOT artifacts
# (artifacts/manifest.json) and skip with a SKIP message when absent;
# the HRR math, golden-parity and engine suites run *unconditionally* —
# the engine falls back to the native pure-Rust backend — so this gate
# reflects real serving-stack health on a fresh checkout. Run
# `make artifacts` first for the additional artifact-path coverage.
# `cargo fmt`/`clippy -D warnings` gate every target, the native
# rust/src/hrr module included.
set -euo pipefail
cd "$(dirname "$0")"

# Static-analysis gate (hrrlint): the project-invariant linter with the
# panic-path ratchet (lint_baseline.json). Runs *before* the cargo
# early-exit below so the gate holds even where the Rust toolchain is
# unavailable — the Python transcription in python/analysis/hrrlint.py
# is byte-for-byte identical to the cargo binary (the parity is pinned
# by rust/tests/lint_self.rs and python/tests/test_hrrlint.py). Any
# finding not in the checked-in baseline fails verify.
if command -v python3 >/dev/null 2>&1; then
    echo "==> python3 python/analysis/hrrlint.py"
    python3 python/analysis/hrrlint.py
fi

if ! command -v cargo >/dev/null 2>&1; then
    echo "verify: SKIP — cargo not found (rust toolchain unavailable in this environment)." >&2
    echo "verify: install rustup (https://rustup.rs) to run the full gate." >&2
    exit 0
fi

run() {
    echo "==> $*"
    "$@"
}

run cargo build --release

# The canonical hrrlint runner: same lexer/rules/report as the Python
# mirror above, exercised here against the real tree and baseline.
run cargo run --release --bin hrrlint

run cargo test -q

# Native-backend suite with artifacts forcibly hidden: property tests,
# golden-vector parity (forward *and* train-curve) and the full engine
# integration suite must pass with zero artifact-skips on a machine that
# has no artifacts/ at all.
run env HRRFORMER_ARTIFACTS=/hrrformer-no-artifacts \
    cargo test -q --test prop_hrr --test golden_native --test golden_train \
    --test integration_engine

# Native hot-path bench smoke (artifact-free): exercises the FFT plan
# cache, the reusable workspaces and the threaded predict fan-out, and
# must regenerate the BENCH_native.json trajectory from scratch.
rm -f BENCH_native.json
run cargo run --release -- bench native --examples 8
if [[ ! -s BENCH_native.json ]]; then
    echo "verify: FAIL — bench native did not write BENCH_native.json" >&2
    exit 1
fi
if ! grep -q '"lint"' BENCH_native.json; then
    echo "verify: FAIL — bench native did not stamp the lint section into BENCH_native.json" >&2
    exit 1
fi

# Streaming smoke (artifact-free): one paper-scale T=131072 stream,
# fed from a memory-mapped corpus in 8192-token chunks, must classify
# end-to-end through the serve --stream engine path with O(H) carried
# state, and `bench stream` must merge a "stream" section into the
# BENCH_native.json trajectory just regenerated above.
run env HRRFORMER_ARTIFACTS=/hrrformer-no-artifacts \
    cargo run --release -- serve --stream --requests 1 --chunk 8192
run env HRRFORMER_ARTIFACTS=/hrrformer-no-artifacts \
    cargo run --release -- bench stream --examples 1 --chunks 8192
if ! grep -q '"stream"' BENCH_native.json; then
    echo "verify: FAIL — bench stream did not merge a stream section into BENCH_native.json" >&2
    exit 1
fi

# HTTP front-door smoke (artifact-free): stand up the real network
# server (`serve --http`) on a local port, drive it with the
# closed-loop `bench http` client over real sockets, and require the
# merged "http" section (throughput + client-side p50/p99 for the
# steady and overload phases) in the trajectory regenerated above.
http_port=18734
env HRRFORMER_ARTIFACTS=/hrrformer-no-artifacts \
    cargo run --release -- serve --http --backend native \
    --bases ember_hrrformer_small_T64_B8 --queue-depth 4 \
    --addr 127.0.0.1:${http_port} --http-secs 20 &
serve_pid=$!
ready=0
for _ in $(seq 1 75); do
    if (exec 3<>"/dev/tcp/127.0.0.1/${http_port}") 2>/dev/null; then
        ready=1
        break
    fi
    sleep 0.2
done
if [[ $ready -ne 1 ]]; then
    echo "verify: FAIL — serve --http never started listening on :${http_port}" >&2
    kill "$serve_pid" 2>/dev/null || true
    exit 1
fi
run env HRRFORMER_ARTIFACTS=/hrrformer-no-artifacts \
    cargo run --release -- bench http --addr 127.0.0.1:${http_port} \
    --clients 2 --requests 8 --overload-clients 8 --overload-requests 4 --req-len 48
wait "$serve_pid"   # --http-secs elapses; the server drains and exits 0
if ! grep -q '"http"' BENCH_native.json; then
    echo "verify: FAIL — bench http did not merge an http section into BENCH_native.json" >&2
    exit 1
fi

# Native training smoke (artifact-free): a tiny `repro train --backend
# native` job must run the full train→eval→checkpoint loop (reverse-mode
# autodiff + Adam, --eval-every exercising the periodic-eval path) and
# end with a finite training loss in the curve CSV.
rm -f results/verify_train_curve.csv
run env HRRFORMER_ARTIFACTS=/hrrformer-no-artifacts \
    cargo run --release -- train --base listops_hrrformer_small_T32_B4 --backend native \
    --steps 4 --eval-every 2 --eval-batches 1 --curve results/verify_train_curve.csv
final_loss=$(awk -F, 'NR>1 {v=$2} END {print v}' results/verify_train_curve.csv)
if ! [[ "$final_loss" =~ ^-?[0-9]+(\.[0-9]+)?$ ]]; then
    echo "verify: FAIL — native train smoke ended with a non-finite loss ('${final_loss:-missing}')" >&2
    exit 1
fi

# Second-architecture training smoke: the same job re-aimed at hgconv
# via --arch (which rewrites the base's model token), with training
# dropout on — eval/predict are dropout-free, so the loss stays finite
# and the curve CSV well-formed exactly like the hrrformer smoke.
rm -f results/verify_train_hgconv.csv
run env HRRFORMER_ARTIFACTS=/hrrformer-no-artifacts \
    cargo run --release -- train --base listops_hrrformer_small_T32_B4 --arch hgconv \
    --backend native --steps 4 --eval-every 2 --eval-batches 1 --dropout 0.1 \
    --curve results/verify_train_hgconv.csv
final_loss=$(awk -F, 'NR>1 {v=$2} END {print v}' results/verify_train_hgconv.csv)
if ! [[ "$final_loss" =~ ^-?[0-9]+(\.[0-9]+)?$ ]]; then
    echo "verify: FAIL — hgconv train smoke ended with a non-finite loss ('${final_loss:-missing}')" >&2
    exit 1
fi

# Native LRA matrix smoke: `bench lra --native` must train + eval BOTH
# architectures across the five LRA loaders (tiny shapes/steps here)
# and write an accuracy matrix keyed by architecture to BENCH_lra.json.
rm -f BENCH_lra.json
run env HRRFORMER_ARTIFACTS=/hrrformer-no-artifacts \
    cargo run --release -- bench lra --native --steps 2 --seq-len 32 --batch 2
for key in '"hrrformer"' '"hgconv"' '"lra_native"'; do
    if ! grep -q "$key" BENCH_lra.json; then
        echo "verify: FAIL — BENCH_lra.json is missing the $key key" >&2
        exit 1
    fi
done

# Hot-reload smoke (artifact-free): train a deployable weight artifact
# (`train --emit-artifact`), stand the HTTP server back up on the
# matching-T bucket, flip it live with `POST /admin/reload`, and require
# /metrics to report the bumped model version. The EMBER presets carry a
# learned positional table of shape (T, E), so the emitted artifact's T
# must match the served bucket's T — both 64 here.
artifact_path=results/verify_weights.hrrart
rm -f "$artifact_path"
run env HRRFORMER_ARTIFACTS=/hrrformer-no-artifacts \
    cargo run --release -- train --base ember_hrrformer_small_T64_B8 --backend native \
    --steps 4 --eval-every 4 --eval-batches 1 --emit-artifact "$artifact_path"
if [[ ! -s "$artifact_path" ]]; then
    echo "verify: FAIL — train --emit-artifact wrote no artifact" >&2
    exit 1
fi
env HRRFORMER_ARTIFACTS=/hrrformer-no-artifacts \
    cargo run --release -- serve --http --backend native \
    --bases ember_hrrformer_small_T64_B8 --queue-depth 4 \
    --addr 127.0.0.1:${http_port} --http-secs 30 &
serve_pid=$!
ready=0
for _ in $(seq 1 75); do
    if (exec 3<>"/dev/tcp/127.0.0.1/${http_port}") 2>/dev/null; then
        ready=1
        break
    fi
    sleep 0.2
done
if [[ $ready -ne 1 ]]; then
    echo "verify: FAIL — serve --http (reload smoke) never started listening on :${http_port}" >&2
    kill "$serve_pid" 2>/dev/null || true
    exit 1
fi
# POST the reload and scrape /metrics over bash's /dev/tcp — no curl
# needed for the gate.
reload_body="{\"path\":\"${PWD}/${artifact_path}\"}"
exec 3<>"/dev/tcp/127.0.0.1/${http_port}"
printf 'POST /admin/reload HTTP/1.1\r\nHost: v\r\nContent-Length: %s\r\nConnection: close\r\n\r\n%s' \
    "${#reload_body}" "$reload_body" >&3
reload_reply=$(cat <&3)
exec 3<&- 3>&-
if ! grep -q '"version":2' <<<"$reload_reply"; then
    echo "verify: FAIL — POST /admin/reload did not flip to version 2: ${reload_reply}" >&2
    kill "$serve_pid" 2>/dev/null || true
    exit 1
fi
exec 3<>"/dev/tcp/127.0.0.1/${http_port}"
printf 'GET /metrics HTTP/1.1\r\nHost: v\r\nConnection: close\r\n\r\n' >&3
metrics_reply=$(cat <&3)
exec 3<&- 3>&-
kill "$serve_pid" 2>/dev/null || true
wait "$serve_pid" 2>/dev/null || true
if ! grep -q '"model_version":2' <<<"$metrics_reply"; then
    echo "verify: FAIL — /metrics does not report model_version 2 after reload: ${metrics_reply}" >&2
    exit 1
fi

# Second-architecture serving smoke: the same HTTP front door on an
# hgconv bucket (--arch rewrites the default base), driven by the real
# closed-loop client; /metrics must echo the bucket's architecture.
env HRRFORMER_ARTIFACTS=/hrrformer-no-artifacts \
    cargo run --release -- serve --http --backend native --arch hgconv \
    --bases ember_hrrformer_small_T64_B8 --queue-depth 4 \
    --addr 127.0.0.1:${http_port} --http-secs 20 &
serve_pid=$!
ready=0
for _ in $(seq 1 75); do
    if (exec 3<>"/dev/tcp/127.0.0.1/${http_port}") 2>/dev/null; then
        ready=1
        break
    fi
    sleep 0.2
done
if [[ $ready -ne 1 ]]; then
    echo "verify: FAIL — serve --http --arch hgconv never started listening on :${http_port}" >&2
    kill "$serve_pid" 2>/dev/null || true
    exit 1
fi
run env HRRFORMER_ARTIFACTS=/hrrformer-no-artifacts \
    cargo run --release -- bench http --addr 127.0.0.1:${http_port} \
    --clients 1 --requests 4 --overload-clients 2 --overload-requests 2 --req-len 48
exec 3<>"/dev/tcp/127.0.0.1/${http_port}"
printf 'GET /metrics HTTP/1.1\r\nHost: v\r\nConnection: close\r\n\r\n' >&3
metrics_reply=$(cat <&3)
exec 3<&- 3>&-
kill "$serve_pid" 2>/dev/null || true
wait "$serve_pid" 2>/dev/null || true
if ! grep -q '"arch":"hgconv"' <<<"$metrics_reply"; then
    echo "verify: FAIL — /metrics does not echo the hgconv bucket architecture: ${metrics_reply}" >&2
    exit 1
fi

if [[ "${1:-}" != "--fast" ]]; then
    run cargo fmt --check
    run cargo clippy --all-targets -- -D warnings
fi

echo "verify: OK"
