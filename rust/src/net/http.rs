//! HTTP/1.1 wire format: incremental request-head parsing, chunked
//! transfer decoding, and response serialization. Zero dependencies and
//! zero protocol state of its own — the connection driver
//! ([`super::conn`]) owns the buffer and calls back in as bytes arrive,
//! so the same functions work under split reads, pipelining, and
//! hostile framing.
//!
//! Hardening posture (this sits on the network):
//! * the head is bounded by [`MAX_HEAD_BYTES`] / [`MAX_HEADERS`] —
//!   oversized heads fail typed ([`HttpParseError::HeadTooLarge`] → 431)
//!   instead of growing the buffer forever;
//! * a request carrying **both** `Content-Length` and
//!   `Transfer-Encoding: chunked` is rejected outright (RFC 7230 §3.3.3
//!   — the classic request-smuggling ambiguity);
//! * chunk sizes are overflow-checked and capped, so a `ffffffffff\r\n`
//!   size line cannot wrap arithmetic or commit the server to reading
//!   petabytes.

use std::fmt;

/// Hard cap on a request head (request line + headers + blank line).
pub const MAX_HEAD_BYTES: usize = 16 * 1024;

/// Hard cap on the number of header lines.
pub const MAX_HEADERS: usize = 64;

/// Largest single chunk a chunked body may declare (16 MiB — same order
/// as the server's body cap; real chunks are orders of magnitude
/// smaller).
const MAX_CHUNK_SIZE: usize = 16 * 1024 * 1024;

/// Typed wire-parse failure; the driver maps it to a status code
/// (431 for [`HttpParseError::HeadTooLarge`], 400 otherwise) and closes
/// the connection, since framing can no longer be trusted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HttpParseError {
    /// Head exceeded [`MAX_HEAD_BYTES`] without terminating.
    HeadTooLarge,
    /// Malformed request line, header, or chunk framing.
    Malformed(&'static str),
}

impl fmt::Display for HttpParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            HttpParseError::HeadTooLarge => write!(f, "request head too large"),
            HttpParseError::Malformed(m) => write!(f, "malformed request: {m}"),
        }
    }
}

impl std::error::Error for HttpParseError {}

/// A parsed request head. Field values are copied out of the read
/// buffer (the head is small and bounded); the *body* stays in the
/// buffer and is handed to handlers as a borrowed slice.
#[derive(Debug, Clone)]
pub struct Head {
    pub method: String,
    /// Path only — the query string (if any) is split off.
    pub path: String,
    /// Raw query string after `?`, without the `?`.
    pub query: String,
    pub content_length: Option<usize>,
    /// `Transfer-Encoding: chunked` framing.
    pub chunked: bool,
    /// Whether the connection may serve another request after this one
    /// (HTTP/1.1 default true, HTTP/1.0 default false, `Connection`
    /// header overrides).
    pub keep_alive: bool,
}

impl Head {
    /// Declared body length for non-chunked requests (no body → 0).
    pub fn body_len(&self) -> usize {
        self.content_length.unwrap_or(0)
    }

    /// Look up a `key=value` pair in the query string.
    pub fn query_param(&self, key: &str) -> Option<&str> {
        self.query.split('&').find_map(|pair| {
            let (k, v) = pair.split_once('=')?;
            (k == key).then_some(v)
        })
    }
}

/// Try to parse a complete request head from the front of `buf`.
///
/// * `Ok(None)` — the head is not complete yet; read more bytes.
/// * `Ok(Some((head, head_len)))` — parsed; the body (if any) starts at
///   `buf[head_len..]`.
/// * `Err(_)` — the head is complete-but-malformed, or `buf` grew past
///   [`MAX_HEAD_BYTES`] without terminating.
pub fn parse_head(buf: &[u8]) -> Result<Option<(Head, usize)>, HttpParseError> {
    // Bound the search: a head that has not terminated within the cap
    // never will be accepted, however much more arrives.
    let window = &buf[..buf.len().min(MAX_HEAD_BYTES)];
    let end = match find_head_end(window) {
        Some(e) => e,
        None => {
            if buf.len() >= MAX_HEAD_BYTES {
                return Err(HttpParseError::HeadTooLarge);
            }
            return Ok(None);
        }
    };
    let head_len = end + 4; // include the \r\n\r\n terminator
    let text = std::str::from_utf8(&buf[..end])
        .map_err(|_| HttpParseError::Malformed("head is not valid utf-8"))?;

    let mut lines = text.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split(' ');
    let method = parts.next().unwrap_or("").to_string();
    let target = parts.next().ok_or(HttpParseError::Malformed("missing request target"))?;
    let version = parts.next().ok_or(HttpParseError::Malformed("missing http version"))?;
    if parts.next().is_some() || method.is_empty() || target.is_empty() {
        return Err(HttpParseError::Malformed("bad request line"));
    }
    let mut keep_alive = match version {
        "HTTP/1.1" => true,
        "HTTP/1.0" => false,
        _ => return Err(HttpParseError::Malformed("unsupported http version")),
    };

    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target.to_string(), String::new()),
    };

    let mut content_length: Option<usize> = None;
    let mut chunked = false;
    let mut n_headers = 0usize;
    for line in lines {
        n_headers += 1;
        if n_headers > MAX_HEADERS {
            return Err(HttpParseError::Malformed("too many headers"));
        }
        let (name, value) =
            line.split_once(':').ok_or(HttpParseError::Malformed("header missing ':'"))?;
        let value = value.trim();
        if name.eq_ignore_ascii_case("content-length") {
            let n = value
                .parse::<usize>()
                .map_err(|_| HttpParseError::Malformed("bad content-length"))?;
            // Duplicate Content-Length headers with differing values are
            // another smuggling vector; identical duplicates are merely
            // redundant.
            if content_length.is_some_and(|prev| prev != n) {
                return Err(HttpParseError::Malformed("conflicting content-length"));
            }
            content_length = Some(n);
        } else if name.eq_ignore_ascii_case("transfer-encoding") {
            // Only `chunked` (as the sole/final coding) is supported.
            if !value.eq_ignore_ascii_case("chunked") {
                return Err(HttpParseError::Malformed("unsupported transfer-encoding"));
            }
            chunked = true;
        } else if name.eq_ignore_ascii_case("connection") {
            if value.eq_ignore_ascii_case("close") {
                keep_alive = false;
            } else if value.eq_ignore_ascii_case("keep-alive") {
                keep_alive = true;
            }
        }
    }
    if chunked && content_length.is_some() {
        // RFC 7230 §3.3.3: the two framings disagree by construction;
        // accepting either interpretation enables request smuggling
        // through any intermediary that picks the other.
        return Err(HttpParseError::Malformed("both content-length and transfer-encoding"));
    }

    Ok(Some((Head { method, path, query, content_length, chunked, keep_alive }, head_len)))
}

/// Byte offset of the `\r\n\r\n` head terminator, if present.
fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// Incremental `Transfer-Encoding: chunked` decoder. Feed it raw bytes
/// as they arrive; it appends decoded body bytes to `out` and reports
/// how much input it consumed, so the caller can keep pipelined
/// requests that follow the terminal chunk intact in its buffer.
pub struct ChunkedDecoder {
    state: ChunkState,
    /// Total decoded bytes — the caller's body-size cap applies to this.
    decoded: usize,
}

enum ChunkState {
    /// Reading the hex size line (possibly a `;ext` to skip).
    Size { size: usize, digits: usize, in_ext: bool, cr: bool },
    /// Copying chunk payload.
    Data { remaining: usize },
    /// Expecting the `\r\n` that terminates a chunk's payload.
    DataEnd { cr: bool },
    /// After the 0-size chunk: skipping trailer lines until the blank
    /// line that ends the message.
    Trailer { line_bytes: usize, cr: bool },
    Done,
}

impl Default for ChunkedDecoder {
    fn default() -> Self {
        ChunkedDecoder::new()
    }
}

impl ChunkedDecoder {
    pub fn new() -> ChunkedDecoder {
        ChunkedDecoder {
            state: ChunkState::Size { size: 0, digits: 0, in_ext: false, cr: false },
            decoded: 0,
        }
    }

    pub fn is_done(&self) -> bool {
        matches!(self.state, ChunkState::Done)
    }

    /// Decoded body bytes so far.
    pub fn decoded(&self) -> usize {
        self.decoded
    }

    /// Consume bytes from `input`, appending decoded payload to `out`.
    /// Returns how many input bytes were consumed; consumption stops at
    /// the end of the message ([`ChunkedDecoder::is_done`]) or when
    /// `input` is exhausted.
    pub fn feed(&mut self, input: &[u8], out: &mut Vec<u8>) -> Result<usize, HttpParseError> {
        let mut pos = 0usize;
        while pos < input.len() {
            match &mut self.state {
                ChunkState::Done => break,
                ChunkState::Size { size, digits, in_ext, cr } => {
                    let b = input[pos];
                    pos += 1;
                    if *cr {
                        if b != b'\n' {
                            return Err(HttpParseError::Malformed("chunk size line: CR without LF"));
                        }
                        if *digits == 0 {
                            return Err(HttpParseError::Malformed("empty chunk size"));
                        }
                        let n = *size;
                        self.state = if n == 0 {
                            ChunkState::Trailer { line_bytes: 0, cr: false }
                        } else {
                            ChunkState::Data { remaining: n }
                        };
                    } else if b == b'\r' {
                        *cr = true;
                    } else if *in_ext {
                        // chunk extension: ignored until end of line
                    } else if b == b';' {
                        *in_ext = true;
                    } else if let Some(d) = (b as char).to_digit(16) {
                        *size = size
                            .checked_mul(16)
                            .and_then(|s| usize::try_from(d).ok().and_then(|d| s.checked_add(d)))
                            .filter(|&s| s <= MAX_CHUNK_SIZE)
                            .ok_or(HttpParseError::Malformed("chunk size too large"))?;
                        *digits += 1;
                    } else {
                        return Err(HttpParseError::Malformed("bad chunk size digit"));
                    }
                }
                ChunkState::Data { remaining } => {
                    let take = (*remaining).min(input.len() - pos);
                    out.extend_from_slice(&input[pos..pos + take]);
                    pos += take;
                    *remaining -= take;
                    self.decoded += take;
                    if *remaining == 0 {
                        self.state = ChunkState::DataEnd { cr: false };
                    }
                }
                ChunkState::DataEnd { cr } => {
                    let b = input[pos];
                    pos += 1;
                    if !*cr {
                        if b != b'\r' {
                            return Err(HttpParseError::Malformed("chunk data not CRLF-terminated"));
                        }
                        *cr = true;
                    } else if b == b'\n' {
                        self.state = ChunkState::Size { size: 0, digits: 0, in_ext: false, cr: false };
                    } else {
                        return Err(HttpParseError::Malformed("chunk data not CRLF-terminated"));
                    }
                }
                ChunkState::Trailer { line_bytes, cr } => {
                    let b = input[pos];
                    pos += 1;
                    if *cr {
                        if b != b'\n' {
                            return Err(HttpParseError::Malformed("trailer: CR without LF"));
                        }
                        if *line_bytes == 0 {
                            self.state = ChunkState::Done;
                        } else {
                            // a (skipped) trailer header line ended;
                            // keep reading lines until the blank one
                            self.state = ChunkState::Trailer { line_bytes: 0, cr: false };
                        }
                    } else if b == b'\r' {
                        *cr = true;
                    } else {
                        *line_bytes += 1;
                        if *line_bytes > MAX_HEAD_BYTES {
                            return Err(HttpParseError::Malformed("trailer line too long"));
                        }
                    }
                }
            }
        }
        Ok(pos)
    }
}

/// Serialize a response with an explicit `Content-Length` (the only
/// framing this server emits) into `out`.
pub fn write_response(
    out: &mut Vec<u8>,
    status: u16,
    content_type: &str,
    body: &[u8],
    keep_alive: bool,
) {
    let head = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n",
        reason(status),
        body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    out.extend_from_slice(head.as_bytes());
    out.extend_from_slice(body);
}

/// Canonical reason phrase for the status codes this server emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        410 => "Gone",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const REQ: &[u8] = b"POST /classify HTTP/1.1\r\nHost: x\r\nContent-Length: 13\r\n\r\n{\"ids\":[1,2]}";

    #[test]
    fn split_reads_parse_only_when_head_is_complete() {
        // Feeding the request one byte at a time: every prefix short of
        // the blank line is "not yet", never an error.
        let full = REQ;
        let head_end = full.windows(4).position(|w| w == b"\r\n\r\n").unwrap() + 4;
        for n in 0..head_end {
            assert!(
                matches!(parse_head(&full[..n]), Ok(None)),
                "prefix of {n} bytes should be incomplete"
            );
        }
        let (head, head_len) = parse_head(full).unwrap().expect("complete head");
        assert_eq!(head_len, head_end);
        assert_eq!(head.method, "POST");
        assert_eq!(head.path, "/classify");
        assert_eq!(head.content_length, Some(13));
        assert!(head.keep_alive);
        assert!(!head.chunked);
    }

    #[test]
    fn oversized_head_is_a_typed_error_not_unbounded_buffering() {
        let mut buf = b"GET / HTTP/1.1\r\n".to_vec();
        while buf.len() < MAX_HEAD_BYTES {
            buf.extend_from_slice(b"X-Pad: aaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaa\r\n");
        }
        assert_eq!(parse_head(&buf), Err(HttpParseError::HeadTooLarge));
    }

    #[test]
    fn too_many_headers_rejected() {
        let mut buf = b"GET / HTTP/1.1\r\n".to_vec();
        for i in 0..=MAX_HEADERS {
            buf.extend_from_slice(format!("X-{i}: v\r\n").as_bytes());
        }
        buf.extend_from_slice(b"\r\n");
        assert_eq!(parse_head(&buf), Err(HttpParseError::Malformed("too many headers")));
    }

    #[test]
    fn pipelined_requests_parse_in_sequence() {
        let mut buf = Vec::new();
        buf.extend_from_slice(b"GET /healthz HTTP/1.1\r\n\r\n");
        buf.extend_from_slice(b"GET /metrics HTTP/1.1\r\nConnection: close\r\n\r\n");
        let (h1, len1) = parse_head(&buf).unwrap().unwrap();
        assert_eq!(h1.path, "/healthz");
        assert!(h1.keep_alive);
        let (h2, len2) = parse_head(&buf[len1..]).unwrap().unwrap();
        assert_eq!(h2.path, "/metrics");
        assert!(!h2.keep_alive);
        assert_eq!(len1 + len2, buf.len());
    }

    #[test]
    fn query_string_splits_and_params_resolve() {
        let buf = b"POST /stream/append?id=7&x=1 HTTP/1.1\r\n\r\n";
        let (h, _) = parse_head(buf).unwrap().unwrap();
        assert_eq!(h.path, "/stream/append");
        assert_eq!(h.query_param("id"), Some("7"));
        assert_eq!(h.query_param("x"), Some("1"));
        assert_eq!(h.query_param("missing"), None);
    }

    #[test]
    fn smuggling_vectors_rejected() {
        // CL + TE together
        let buf =
            b"POST / HTTP/1.1\r\nContent-Length: 4\r\nTransfer-Encoding: chunked\r\n\r\n";
        assert!(matches!(parse_head(buf), Err(HttpParseError::Malformed(_))));
        // conflicting duplicate CL
        let buf = b"POST / HTTP/1.1\r\nContent-Length: 4\r\nContent-Length: 5\r\n\r\n";
        assert!(matches!(parse_head(buf), Err(HttpParseError::Malformed(_))));
        // identical duplicate CL is redundant but unambiguous
        let buf = b"POST / HTTP/1.1\r\nContent-Length: 4\r\nContent-Length: 4\r\n\r\n";
        let (h, _) = parse_head(buf).unwrap().unwrap();
        assert_eq!(h.content_length, Some(4));
    }

    #[test]
    fn http_10_defaults_to_close() {
        let (h, _) = parse_head(b"GET / HTTP/1.0\r\n\r\n").unwrap().unwrap();
        assert!(!h.keep_alive);
        let (h, _) =
            parse_head(b"GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n").unwrap().unwrap();
        assert!(h.keep_alive);
        assert!(matches!(
            parse_head(b"GET / HTTP/2\r\n\r\n"),
            Err(HttpParseError::Malformed(_))
        ));
    }

    #[test]
    fn chunked_decoder_reassembles_across_arbitrary_splits() {
        let wire = b"4\r\nWiki\r\n5\r\npedia\r\n0\r\n\r\n";
        // whole-buffer and every split point must agree
        for split in 0..wire.len() {
            let mut dec = ChunkedDecoder::new();
            let mut out = Vec::new();
            let used1 = dec.feed(&wire[..split], &mut out).unwrap();
            assert_eq!(used1, split, "decoder must consume everything pre-terminal");
            let used2 = dec.feed(&wire[split..], &mut out).unwrap();
            assert!(dec.is_done());
            assert_eq!(out, b"Wikipedia");
            assert_eq!(split + used2, wire.len());
            assert_eq!(dec.decoded(), 9);
        }
    }

    #[test]
    fn chunked_decoder_stops_at_message_end_preserving_pipelined_bytes() {
        let wire = b"3\r\nabc\r\n0\r\n\r\nGET /next HTTP/1.1\r\n\r\n";
        let mut dec = ChunkedDecoder::new();
        let mut out = Vec::new();
        let used = dec.feed(wire, &mut out).unwrap();
        assert!(dec.is_done());
        assert_eq!(out, b"abc");
        assert_eq!(&wire[used..], b"GET /next HTTP/1.1\r\n\r\n");
    }

    #[test]
    fn chunk_extensions_and_trailers_are_skipped() {
        let wire = b"4;name=val\r\nWiki\r\n0\r\nX-Trailer: ignored\r\n\r\n";
        let mut dec = ChunkedDecoder::new();
        let mut out = Vec::new();
        dec.feed(wire, &mut out).unwrap();
        assert!(dec.is_done());
        assert_eq!(out, b"Wiki");
    }

    #[test]
    fn hostile_chunk_framing_rejected() {
        // overflow-scale size line
        let mut dec = ChunkedDecoder::new();
        assert!(dec.feed(b"fffffffffffffff\r\n", &mut Vec::new()).is_err());
        // bare LF where CRLF is required
        let mut dec = ChunkedDecoder::new();
        assert!(dec.feed(b"3\nabc", &mut Vec::new()).is_err());
        // missing size digits
        let mut dec = ChunkedDecoder::new();
        assert!(dec.feed(b"\r\n", &mut Vec::new()).is_err());
        // payload not CRLF-terminated
        let mut dec = ChunkedDecoder::new();
        assert!(dec.feed(b"3\r\nabcXX", &mut Vec::new()).is_err());
    }

    #[test]
    fn responses_serialize_with_explicit_length_and_connection() {
        let mut out = Vec::new();
        write_response(&mut out, 429, "application/json", b"{\"error\":\"x\"}", true);
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 429 Too Many Requests\r\n"));
        assert!(s.contains("Content-Length: 13\r\n"));
        assert!(s.contains("Connection: keep-alive\r\n"));
        assert!(s.ends_with("\r\n\r\n{\"error\":\"x\"}"));
        let mut out = Vec::new();
        write_response(&mut out, 200, "application/json", b"{}", false);
        assert!(String::from_utf8(out).unwrap().contains("Connection: close\r\n"));
    }
}
