//! Property tests for the native HRR algebra (rust/src/hrr), on the
//! repo's `util::prop` harness. Always runs — pure math, no artifacts.
//!
//! Invariants pinned here (paper §2-3 + Learning-with-HRRs):
//! * FFT/inverse-FFT and rFFT/irFFT roundtrips, power-of-two and not;
//! * binding is the circular convolution it claims to be, and commutes;
//! * binding-then-unbinding with the stabilized exact inverse recovers
//!   the value within tolerance;
//! * with unit-magnitude projected keys, the cheap involution inverse
//!   recovers the value too;
//! * binding is bilinear, so superpositions decompose linearly;
//! * a precomputed `FftPlan` matches the direct per-call transforms
//!   (both radix-2 and naive-DFT lengths) within 1e-12;
//! * `NativeSession::predict` is bit-deterministic in its scheduler:
//!   sequential, scoped threads at any count, and the shared worker
//!   pool at any budget all produce identical logits.

use std::sync::Arc;

use hrrformer::hrr::{fft, ops, plan::with_plan, FftPlan, HrrConfig, NativeSession, RowScheduler};
use hrrformer::runtime::Tensor;
use hrrformer::util::pool::WorkerPool;
use hrrformer::util::prop::forall;
use hrrformer::util::rng::Rng;

/// Mixed power-of-two and odd lengths, 4..=64 — the head-dim range.
fn dim(rng: &mut Rng) -> usize {
    const DIMS: [usize; 8] = [4, 6, 8, 12, 16, 24, 32, 64];
    DIMS[rng.usize_below(DIMS.len())]
}

fn vec_f32(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32).collect()
}

/// A random vector whose spectrum has no near-zero bin. The stabilized
/// exact inverse divides by `|F(k)|² + ε`, so a key with a ~zero bin
/// *correctly* loses that component — recovery guarantees only hold for
/// well-conditioned keys, which is what this generator produces.
fn well_conditioned(rng: &mut Rng, n: usize) -> Vec<f32> {
    loop {
        let k = vec_f32(rng, n);
        let (re, im) = fft::rfft(&k.iter().map(|&v| v as f64).collect::<Vec<_>>());
        let min_power = re
            .iter()
            .zip(&im)
            .map(|(&r, &i)| r * r + i * i)
            .fold(f64::INFINITY, f64::min);
        if min_power > 1e-2 {
            return k;
        }
    }
}

fn vec_f64(rng: &mut Rng, n: usize) -> Vec<f64> {
    (0..n).map(|_| rng.normal()).collect()
}

fn rel_l2(got: &[f32], want: &[f32]) -> f64 {
    let err: f64 = got
        .iter()
        .zip(want)
        .map(|(&g, &w)| (g as f64 - w as f64) * (g as f64 - w as f64))
        .sum();
    let norm: f64 = want.iter().map(|&w| w as f64 * w as f64).sum();
    (err / norm.max(1e-12)).sqrt()
}

#[test]
fn fft_inverse_fft_roundtrip() {
    forall(200, 0x0FF7_0001, |rng| {
        let n = dim(rng);
        let re0 = vec_f64(rng, n);
        let im0 = vec_f64(rng, n);
        let mut re = re0.clone();
        let mut im = im0.clone();
        fft::fft(&mut re, &mut im, false);
        fft::fft(&mut re, &mut im, true);
        for i in 0..n {
            assert!((re[i] - re0[i]).abs() < 1e-9, "re[{i}] n={n}");
            assert!((im[i] - im0[i]).abs() < 1e-9, "im[{i}] n={n}");
        }
    });
}

#[test]
fn rfft_irfft_roundtrip() {
    forall(200, 0x0FF7_0002, |rng| {
        let n = dim(rng);
        let x = vec_f64(rng, n);
        let (re, im) = fft::rfft(&x);
        assert_eq!(re.len(), fft::num_bins(n));
        let back = fft::irfft(&re, &im, n);
        for i in 0..n {
            assert!((back[i] - x[i]).abs() < 1e-9, "x[{i}] n={n}");
        }
    });
}

/// A planned transform must agree with the direct (per-call sin/cos)
/// implementation on every length class — power-of-two radix-2 and
/// non-power-of-two naive DFT, forward and inverse, complex and real
/// pairs. The plan builds its tables with the same float expressions,
/// so agreement is bit-exact; 1e-12 is the contract.
#[test]
fn planned_fft_matches_unplanned_fft() {
    forall(200, 0x0FF7_0009, |rng| {
        let n = 1 + rng.usize_below(64); // arbitrary: pow2 and not
        let re0 = vec_f64(rng, n);
        let im0 = vec_f64(rng, n);
        let mut plan = FftPlan::new(n);
        for inverse in [false, true] {
            let mut re_d = re0.clone();
            let mut im_d = im0.clone();
            fft::fft(&mut re_d, &mut im_d, inverse);
            let mut re_p = re0.clone();
            let mut im_p = im0.clone();
            plan.fft(&mut re_p, &mut im_p, inverse);
            for i in 0..n {
                assert!((re_d[i] - re_p[i]).abs() <= 1e-12, "re[{i}] n={n} inverse={inverse}");
                assert!((im_d[i] - im_p[i]).abs() <= 1e-12, "im[{i}] n={n} inverse={inverse}");
            }
        }
        // real pair, through the thread-local cache ops.rs uses
        let x = vec_f64(rng, n);
        let (dr, di) = fft::rfft(&x);
        let (pr, pi) = with_plan(n, |p| p.rfft(&x));
        for j in 0..dr.len() {
            assert!((dr[j] - pr[j]).abs() <= 1e-12, "rfft re[{j}] n={n}");
            assert!((di[j] - pi[j]).abs() <= 1e-12, "rfft im[{j}] n={n}");
        }
        let back_d = fft::irfft(&dr, &di, n);
        let back_p = with_plan(n, |p| p.irfft(&pr, &pi));
        for i in 0..n {
            assert!((back_d[i] - back_p[i]).abs() <= 1e-12, "irfft[{i}] n={n}");
        }
    });
}

/// Every scheduler — single-threaded, scoped fan-out at any worker
/// count, and the shared pool at any budget — must produce
/// *bit-identical* logits: rows are independent, each worker owns its
/// scratch workspace, and the partitioning/interleaving only changes
/// wall-clock. One config per FFT path (radix-2 head dim and naive-DFT
/// head dim), with PAD tails and a fully-PAD row in the batch.
#[test]
fn multithreaded_predict_is_bit_identical_to_single_threaded() {
    let configs = [
        ("pow2-head", 16usize, 2usize, false), // head_dim 8 → radix-2
        ("naive-head", 24, 2, true),           // head_dim 12 → naive DFT
    ];
    for (label, embed, heads, learned_pos) in configs {
        let cfg = HrrConfig {
            arch: hrrformer::hrr::Arch::Hrrformer,
            task: "test".into(),
            vocab: 32,
            seq_len: 24,
            batch: 8,
            embed,
            mlp_dim: 48,
            heads,
            layers: 2,
            classes: 3,
            learned_pos,
        };
        let sess = NativeSession::from_config(cfg, 11).unwrap();
        let (b, t) = (7usize, 24usize); // b deliberately not a worker multiple
        let mut rng = Rng::new(0x0FF7_000A);
        let mut ids = vec![0i32; b * t];
        for (r, row) in ids.chunks_mut(t).enumerate() {
            if r == 3 {
                continue; // keep one all-PAD row in the middle
            }
            let live = 1 + rng.usize_below(t);
            for v in row[..live].iter_mut() {
                *v = 1 + rng.usize_below(31) as i32;
            }
        }
        let ids = Tensor::i32(vec![b, t], ids);
        let single = sess.predict_threaded(&ids, 1).unwrap();
        for threads in [2usize, 3, 5, 16] {
            let multi = sess.predict_threaded(&ids, threads).unwrap();
            assert_eq!(
                single.as_f32().unwrap(),
                multi.as_f32().unwrap(),
                "{label}: logits drifted at {threads} worker threads"
            );
            let pool = Arc::new(WorkerPool::new(threads));
            let pooled = sess.predict_with(&ids, &RowScheduler::Pool(pool)).unwrap();
            assert_eq!(
                single.as_f32().unwrap(),
                pooled.as_f32().unwrap(),
                "{label}: pool-scheduled logits drifted at budget {threads}"
            );
        }
        // a shared pool reused across several predicts (the engine's
        // actual usage pattern) must stay bit-identical too
        let pool = Arc::new(WorkerPool::new(3));
        let sched = RowScheduler::Pool(pool);
        for _ in 0..3 {
            let again = sess.predict_with(&ids, &sched).unwrap();
            assert_eq!(
                single.as_f32().unwrap(),
                again.as_f32().unwrap(),
                "{label}: reused-pool logits drifted"
            );
        }
    }
}

#[test]
fn bind_is_circular_convolution_and_commutes() {
    forall(150, 0x0FF7_0003, |rng| {
        let n = dim(rng);
        let x = vec_f32(rng, n);
        let y = vec_f32(rng, n);
        let xy = ops::bind(&x, &y);
        // direct O(n²) circular convolution in f64
        for i in 0..n {
            let mut want = 0.0f64;
            for j in 0..n {
                want += x[j] as f64 * y[(i + n - j) % n] as f64;
            }
            assert!((xy[i] as f64 - want).abs() < 1e-3, "lag {i} n={n}");
        }
        let yx = ops::bind(&y, &x);
        for i in 0..n {
            assert!((xy[i] - yx[i]).abs() < 1e-4, "commutativity at {i}");
        }
    });
}

#[test]
fn bind_then_unbind_recovers_the_value() {
    forall(200, 0x0FF7_0004, |rng| {
        let n = dim(rng);
        let k = well_conditioned(rng, n);
        let v = vec_f32(rng, n);
        let s = ops::bind(&k, &v);
        let v_hat = ops::unbind(&s, &k);
        // The ε-stabilized inverse leaves a bias of ~ε/|F(k)|² per bin,
        // so recovery is near-exact, not bit-exact.
        let err = rel_l2(&v_hat, &v);
        assert!(err < 1e-3, "relative L2 error {err} (n={n})");
        assert!(ops::cosine(&v_hat, &v) > 0.999, "cosine similarity too low (n={n})");
    });
}

#[test]
fn projected_keys_make_the_involution_inverse_exact() {
    forall(200, 0x0FF7_0005, |rng| {
        let n = dim(rng);
        let k = ops::projection(&vec_f32(rng, n));
        let v = vec_f32(rng, n);
        let s = ops::bind(&k, &v);
        // With |F(k)| ≡ 1, Plate's involution is an exact inverse.
        let v_hat = ops::bind(&ops::approx_inverse(&k), &s);
        for i in 0..n {
            assert!((v_hat[i] - v[i]).abs() < 1e-3, "element {i} n={n}");
        }
    });
}

#[test]
fn binding_is_bilinear_so_superposition_is_linear() {
    forall(150, 0x0FF7_0006, |rng| {
        let n = dim(rng);
        let k = vec_f32(rng, n);
        let v1 = vec_f32(rng, n);
        let v2 = vec_f32(rng, n);
        let a = (rng.f64() * 4.0 - 2.0) as f32;
        // bind(k, a·v1 + v2) == a·bind(k, v1) + bind(k, v2)
        let lhs_in: Vec<f32> = v1.iter().zip(&v2).map(|(&x, &y)| a * x + y).collect();
        let lhs = ops::bind(&k, &lhs_in);
        let b1 = ops::bind(&k, &v1);
        let b2 = ops::bind(&k, &v2);
        for i in 0..n {
            let rhs = a * b1[i] + b2[i];
            assert!((lhs[i] - rhs).abs() < 1e-3, "element {i} n={n}");
        }
        // and unbinding distributes over the superposition
        let q = well_conditioned(rng, n);
        let sum: Vec<f32> = b1.iter().zip(&b2).map(|(&x, &y)| x + y).collect();
        let u_sum = ops::unbind(&sum, &q);
        let u1 = ops::unbind(&b1, &q);
        let u2 = ops::unbind(&b2, &q);
        for i in 0..n {
            assert!((u_sum[i] - (u1[i] + u2[i])).abs() < 1e-3, "unbind linearity at {i}");
        }
    });
}

#[test]
fn superpose_bound_matches_per_pair_binding() {
    forall(100, 0x0FF7_0007, |rng| {
        let n = dim(rng);
        let pairs: Vec<(Vec<f32>, Vec<f32>)> =
            (0..1 + rng.usize_below(5)).map(|_| (vec_f32(rng, n), vec_f32(rng, n))).collect();
        let refs: Vec<(&[f32], &[f32])> =
            pairs.iter().map(|(x, y)| (x.as_slice(), y.as_slice())).collect();
        let fused = ops::superpose_bound(&refs, n);
        let mut want = vec![0.0f64; n];
        for (x, y) in &pairs {
            for (w, b) in want.iter_mut().zip(ops::bind(x, y)) {
                *w += b as f64;
            }
        }
        for i in 0..n {
            assert!((fused[i] as f64 - want[i]).abs() < 1e-3, "element {i} n={n}");
        }
    });
}

#[test]
fn cosine_is_bounded_and_symmetric() {
    forall(150, 0x0FF7_0008, |rng| {
        let n = dim(rng);
        let a = vec_f32(rng, n);
        let b = vec_f32(rng, n);
        let c = ops::cosine(&a, &b);
        assert!(c.abs() <= 1.0 + 1e-5, "cosine out of bounds: {c}");
        assert!((c - ops::cosine(&b, &a)).abs() < 1e-6, "cosine asymmetry");
        assert!(ops::cosine(&a, &a) > 0.999, "self-similarity");
    });
}
