"""Mixer zoo: one module per token-mixing strategy (DESIGN.md §L2).

Every mixer exposes ``init(key, cfg) -> params`` and
``apply(params, cfg, x, mask, *, rng=None, deterministic=True) -> (B,T,E)``.
``hrrformer`` additionally exposes ``apply_with_weights`` for the Fig 5/9
attention-map dumps.
"""

from . import (  # noqa: F401
    fnet,
    hrrformer,
    linear_transformer,
    linformer,
    local,
    luna,
    performer,
    transformer,
)

MIXERS = {
    "hrrformer": hrrformer,
    "transformer": transformer,
    "fnet": fnet,
    "linformer": linformer,
    "performer": performer,
    "linear_transformer": linear_transformer,
    "local": local,
    "luna": luna,
}
