//! Training orchestrator: epochs, data streams, eval, checkpointing and
//! learning-curve logging around any [`Trainable`] session.
//!
//! Mirrors the paper's protocol (softmax-CE + Adam + exponential LR
//! decay, all inside the session's train step); the trainer owns
//! batching, the train/test streams, and the Fig 8-style per-epoch
//! curve. It is backend-neutral: [`train`] drives the exported
//! `train_step` programs on PJRT, [`train_native`] drives the pure-Rust
//! reverse-mode session (`hrr::NativeTrainSession`) with **zero**
//! artifacts, and both delegate to the same [`train_session`] loop.
//!
//! Timing is split: `train_secs` accumulates optimizer-step time only,
//! and throughput derives from it — eval batches, CSV/stderr logging and
//! checkpoint saves count toward `total_secs` but can no longer inflate
//! `examples_per_sec`. Eval metrics may be absent (timing-only artifact
//! exports) or non-finite; the report carries the last *finite* eval
//! point and the CSV writes empty cells for non-finite values (the CSV
//! mirror of `util::json`'s non-finite → null rule).

use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::data::{batch::BatchStream, by_task, Split};
use crate::hrr::NativeTrainSession;
use crate::metrics::{finite_cell, CsvLogger};
use crate::model::{Session, Trainable, TrainSession};
use crate::runtime::{Manifest, Runtime};
use crate::util::timed;

#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Program base key, e.g. `listops_hrrformer_small_T512_B8`.
    pub base: String,
    pub seed: u64,
    pub steps: usize,
    /// Evaluate every N steps; **0 = final eval only** (there is always
    /// an eval point at the last step either way).
    pub eval_every: usize,
    pub eval_batches: usize,
    /// Where to write the learning-curve CSV (None = no file).
    pub curve_csv: Option<PathBuf>,
    pub ckpt: Option<PathBuf>,
    /// Where to write a versioned weight artifact (manifest +
    /// checksummed payload, deployable via `POST /admin/reload`) after
    /// the last step. Only the native backend produces artifacts.
    pub artifact: Option<PathBuf>,
    /// Embedding/residual dropout probability for the native backend
    /// (0.0 = off, the default). Active only inside `train_step`; eval
    /// and predict are untouched. PJRT sessions ignore it — their
    /// train_step programs were exported without dropout.
    pub dropout: f64,
    /// Keep only the N newest `.hrrart` artifacts in the emitted
    /// artifact's directory after a successful emit (0 = unlimited).
    /// The just-emitted artifact is always protected from pruning.
    pub keep_artifacts: usize,
    pub verbose: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            base: String::new(),
            seed: 0,
            steps: 200,
            eval_every: 50,
            eval_batches: 8,
            curve_csv: None,
            ckpt: None,
            artifact: None,
            dropout: 0.0,
            keep_artifacts: 0,
            verbose: true,
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct EpochPoint {
    pub step: u32,
    pub train_loss: f32,
    pub train_acc: f32,
    pub test_loss: f32,
    pub test_acc: f32,
    pub secs: f64,
}

impl EpochPoint {
    /// Whether this point carries real (finite) eval metrics.
    fn has_finite_eval(&self) -> bool {
        self.test_loss.is_finite() && self.test_acc.is_finite()
    }
}

#[derive(Debug, Clone, Default)]
pub struct TrainReport {
    pub base: String,
    pub curve: Vec<EpochPoint>,
    pub final_train_acc: f32,
    /// Test accuracy at the last eval point with *finite* metrics (NaN
    /// only when no eval ever produced one — e.g. timing-only
    /// artifacts; `util::json` serializes that as null downstream).
    pub final_test_acc: f32,
    /// Wall clock of the whole job: train steps, eval, logging, ckpt.
    pub total_secs: f64,
    /// Time spent inside train steps only — the throughput basis.
    pub train_secs: f64,
    pub steps: usize,
    /// `steps · batch / train_secs`: optimizer throughput, not job
    /// throughput — eval and logging no longer inflate it.
    pub examples_per_sec: f64,
    pub param_scalars: usize,
}

impl TrainReport {
    /// Train/test gap — the paper's Table 2 "overfitting" column.
    pub fn overfit(&self) -> f32 {
        self.final_train_acc - self.final_test_acc
    }
}

/// Run a full training job on the artifact backend: the exported
/// `<base>_train_step` / `<base>_eval_step` programs on PJRT.
pub fn train(rt: &Runtime, manifest: &Manifest, cfg: &TrainConfig) -> Result<TrainReport> {
    let spec = manifest.get(&format!("{}_train_step", cfg.base))?;
    let task = spec.task.clone();
    let vocab = spec.vocab;
    let mut sess = TrainSession::create(rt, manifest, &cfg.base, cfg.seed as u32)?;
    train_session(&mut sess, &task, vocab, cfg)
}

/// Run a full training job on the native backend: pure-Rust reverse-mode
/// autodiff + Adam, no artifacts, no PJRT (`--backend native` on the
/// CLI). The base string resolves against the native preset tables.
pub fn train_native(cfg: &TrainConfig) -> Result<TrainReport> {
    let mut sess = NativeTrainSession::create(&cfg.base, cfg.seed as u32)?;
    if cfg.dropout > 0.0 {
        // masks derive from (seed, step, row, site), so the trajectory
        // is reproducible for a fixed TrainConfig seed
        sess.set_dropout(cfg.dropout, cfg.seed)?;
    }
    let task = sess.cfg().task.clone();
    let vocab = sess.cfg().vocab;
    train_session(&mut sess, &task, vocab, cfg)
}

/// The backend-neutral training loop: batches from the task's synthetic
/// stream, periodic eval, curve CSV, checkpoint. `task`/`vocab` describe
/// the dataset (the session itself only knows shapes).
pub fn train_session(
    sess: &mut dyn Trainable,
    task: &str,
    vocab: usize,
    cfg: &TrainConfig,
) -> Result<TrainReport> {
    let (batch_size, seq_len) = (sess.batch(), sess.seq_len());
    let ds = by_task(task, seq_len).with_context(|| format!("no dataset for task '{task}'"))?;
    anyhow::ensure!(
        ds.vocab() <= vocab,
        "dataset vocab {} exceeds model vocab {}",
        ds.vocab(),
        vocab
    );
    let mut train_stream =
        BatchStream::new(ds.as_ref(), Split::Train, cfg.seed, batch_size, seq_len);

    let param_scalars = sess.param_scalars();
    if cfg.verbose {
        eprintln!(
            "[train] {} — {} params, B={} T={} steps={}",
            cfg.base, param_scalars, batch_size, seq_len, cfg.steps
        );
    }

    let mut csv = match &cfg.curve_csv {
        Some(p) => Some(CsvLogger::create(
            p.clone(),
            &["step", "train_loss", "train_acc", "test_loss", "test_acc", "secs"],
        )?),
        None => None,
    };

    let mut curve: Vec<EpochPoint> = Vec::new();
    let mut window_loss = 0.0f32;
    let mut window_acc = 0.0f32;
    let mut window_n = 0usize;
    let mut train_secs = 0.0f64;
    let t_start = std::time::Instant::now();

    for step in 0..cfg.steps {
        let batch = train_stream.next_batch();
        // only the optimizer step counts toward throughput
        let (stats, dt) = timed(|| sess.train_step(&batch.ids, &batch.labels));
        let stats = stats?;
        train_secs += dt;
        window_loss += stats.loss;
        window_acc += stats.acc;
        window_n += 1;

        // eval_every = 0 means "final eval only" — and the final step
        // always gets an eval point (regression: `% 0` used to panic)
        let at_eval =
            step + 1 == cfg.steps || (cfg.eval_every != 0 && (step + 1) % cfg.eval_every == 0);
        if at_eval {
            // timing-only artifacts have no eval_step — skip test metrics
            let (test_loss, test_acc) = if sess.has_eval() && cfg.eval_batches > 0 {
                evaluate(sess, ds.as_ref(), cfg.seed, cfg.eval_batches, batch_size, seq_len)?
            } else {
                (f32::NAN, f32::NAN)
            };
            let point = EpochPoint {
                step: stats.step,
                train_loss: window_loss / window_n.max(1) as f32,
                train_acc: window_acc / window_n.max(1) as f32,
                test_loss,
                test_acc,
                secs: t_start.elapsed().as_secs_f64(),
            };
            if cfg.verbose {
                eprintln!(
                    "[train] step {:>5}  loss {:.4}  acc {:.3} | test loss {:.4} acc {:.3} | {:.1}s",
                    point.step, point.train_loss, point.train_acc, point.test_loss,
                    point.test_acc, point.secs
                );
            }
            if let Some(csv) = csv.as_mut() {
                // non-finite metrics become empty cells, never "NaN"
                csv.log(&[
                    point.step.to_string(),
                    finite_cell(point.train_loss as f64, 6),
                    finite_cell(point.train_acc as f64, 4),
                    finite_cell(point.test_loss as f64, 6),
                    finite_cell(point.test_acc as f64, 4),
                    format!("{:.2}", point.secs),
                ])?;
            }
            curve.push(point);
            window_loss = 0.0;
            window_acc = 0.0;
            window_n = 0;
        }
    }

    if let Some(p) = &cfg.ckpt {
        if let Some(dir) = p.parent() {
            std::fs::create_dir_all(dir)?;
        }
        sess.save(p)?;
        if cfg.verbose {
            eprintln!("[train] checkpoint → {}", p.display());
        }
    }

    let total_secs = t_start.elapsed().as_secs_f64();
    let last = curve.last().cloned().unwrap_or_default();
    // the headline test metric comes from the last *finite* eval point,
    // so timing-only runs or a transient NaN eval cannot poison the
    // report (and the bench JSON built from it)
    let last_finite = curve.iter().rev().find(|p| p.has_finite_eval());

    if let Some(p) = &cfg.artifact {
        if let Some(dir) = p.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let final_eval = last_finite.map(|pt| (pt.test_loss, pt.test_acc));
        sess.save_artifact(p, final_eval)?;
        if cfg.verbose {
            eprintln!("[train] artifact → {}", p.display());
        }
        // retention: bound the artifact directory, never touching the
        // artifact we just emitted (it may already be serving)
        if cfg.keep_artifacts > 0 {
            if let Some(dir) = p.parent() {
                let deleted =
                    crate::model::prune_keep_last(dir, cfg.keep_artifacts, &[p.clone()])?;
                if cfg.verbose && !deleted.is_empty() {
                    eprintln!("[train] pruned {} old artifact(s)", deleted.len());
                }
            }
        }
    }

    Ok(TrainReport {
        base: cfg.base.clone(),
        final_train_acc: last.train_acc,
        final_test_acc: last_finite.map_or(f32::NAN, |p| p.test_acc),
        curve,
        total_secs,
        train_secs,
        steps: cfg.steps,
        examples_per_sec: (cfg.steps * batch_size) as f64 / train_secs.max(1e-9),
        param_scalars,
    })
}

/// Average eval loss/acc over `n_batches` deterministic test batches.
pub fn evaluate(
    sess: &dyn Trainable,
    ds: &dyn crate::data::Dataset,
    seed: u64,
    n_batches: usize,
    batch: usize,
    seq_len: usize,
) -> Result<(f32, f32)> {
    let mut stream = BatchStream::new(ds, Split::Test, seed, batch, seq_len);
    let mut loss = 0.0f32;
    let mut acc = 0.0f32;
    for _ in 0..n_batches {
        let b = stream.next_batch();
        let s = sess.eval_step(&b.ids, &b.labels)?;
        loss += s.loss;
        acc += s.acc;
    }
    Ok((loss / n_batches as f32, acc / n_batches as f32))
}

/// Time one train step (compile excluded) — used by the speed benches.
pub fn time_one_step(rt: &Runtime, manifest: &Manifest, base: &str, seed: u64) -> Result<f64> {
    let spec = manifest.get(&format!("{base}_train_step"))?;
    let ds = by_task(&spec.task, spec.seq_len).context("dataset")?;
    let mut stream = BatchStream::new(ds.as_ref(), Split::Train, seed, spec.batch, spec.seq_len);
    let mut sess = TrainSession::create(rt, manifest, base, seed as u32)?;
    let warm = stream.next_batch();
    sess.train_step(&warm.ids, &warm.labels)?; // warm-up (first-exec overhead)
    let b = stream.next_batch();
    let (res, secs) = timed(|| sess.train_step(&b.ids, &b.labels));
    res?;
    Ok(secs)
}

#[cfg(test)]
mod tests {
    use std::sync::atomic::{AtomicU32, Ordering};
    use std::time::Duration;

    use super::*;
    use crate::model::{Session, StepStats};
    use crate::runtime::Tensor;

    /// A fake Trainable with controllable timing and eval behavior, so
    /// the loop's accounting is testable without any backend.
    struct StubSession {
        step: u32,
        train_sleep: Duration,
        eval_sleep: Duration,
        has_eval: bool,
        /// evals return finite metrics for the first `finite_evals`
        /// calls, NaN afterwards
        finite_evals: u32,
        evals_seen: AtomicU32,
    }

    impl StubSession {
        fn new() -> StubSession {
            StubSession {
                step: 0,
                train_sleep: Duration::from_millis(2),
                eval_sleep: Duration::from_millis(10),
                has_eval: true,
                finite_evals: u32::MAX,
                evals_seen: AtomicU32::new(0),
            }
        }
    }

    impl Session for StubSession {
        fn batch(&self) -> usize {
            2
        }

        fn seq_len(&self) -> usize {
            8
        }

        fn param_scalars(&self) -> usize {
            0
        }
    }

    impl Trainable for StubSession {
        fn train_step(&mut self, _ids: &Tensor, _labels: &Tensor) -> Result<StepStats> {
            std::thread::sleep(self.train_sleep);
            self.step += 1;
            Ok(StepStats { step: self.step, loss: 1.0 / self.step as f32, acc: 0.5 })
        }

        fn eval_step(&self, _ids: &Tensor, _labels: &Tensor) -> Result<StepStats> {
            std::thread::sleep(self.eval_sleep);
            let n = self.evals_seen.fetch_add(1, Ordering::Relaxed);
            let (loss, acc) = if n < self.finite_evals { (0.9, 0.6) } else { (f32::NAN, f32::NAN) };
            Ok(StepStats { step: self.step, loss, acc })
        }

        fn has_eval(&self) -> bool {
            self.has_eval
        }

        fn save(&self, _path: &std::path::Path) -> Result<()> {
            Ok(())
        }

        fn restore(&mut self, _path: &std::path::Path) -> Result<()> {
            Ok(())
        }
    }

    fn cfg(steps: usize, eval_every: usize) -> TrainConfig {
        TrainConfig {
            base: "stub".into(),
            steps,
            eval_every,
            eval_batches: 1,
            verbose: false,
            ..TrainConfig::default()
        }
    }

    #[test]
    fn eval_every_zero_means_final_eval_only() {
        // regression: `(step + 1) % 0` used to panic with a division by
        // zero the moment --eval-every 0 reached the loop
        let mut sess = StubSession::new();
        let report = train_session(&mut sess, "ember", 300, &cfg(5, 0)).unwrap();
        assert_eq!(report.curve.len(), 1, "exactly one (final) eval point");
        assert_eq!(report.curve[0].step, 5);
        assert!(report.final_test_acc.is_finite());
    }

    #[test]
    fn examples_per_sec_counts_train_step_time_only() {
        let mut sess = StubSession::new();
        // eval after every step, expensive evals: job time >> train time
        let report = train_session(&mut sess, "ember", 300, &cfg(4, 1)).unwrap();
        assert!(report.train_secs > 0.0);
        assert!(
            report.total_secs > report.train_secs,
            "eval/log time must not count as train time"
        );
        let want = (4 * 2) as f64 / report.train_secs;
        assert!(
            (report.examples_per_sec - want).abs() < 1e-9,
            "throughput must derive from train_secs: {} vs {}",
            report.examples_per_sec,
            want
        );
        // the old accounting (total_secs) would have reported less
        assert!(report.examples_per_sec > (4 * 2) as f64 / report.total_secs);
    }

    #[test]
    fn no_eval_backend_reports_nan_but_csv_gets_empty_cells() {
        let mut sess = StubSession::new();
        sess.has_eval = false;
        let dir = std::env::temp_dir().join("hrrformer_trainer_nan_csv");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("curve.csv");
        let _ = std::fs::remove_file(&path);
        let mut c = cfg(4, 2);
        c.curve_csv = Some(path.clone());
        let report = train_session(&mut sess, "ember", 300, &c).unwrap();
        assert!(report.final_test_acc.is_nan(), "no eval ever ran");
        assert!(report.overfit().is_nan());
        let content = std::fs::read_to_string(&path).unwrap();
        assert!(!content.contains("NaN"), "no textual NaN in the CSV: {content}");
        for line in content.lines().skip(1) {
            assert_eq!(line.split(',').count(), 6, "empty cells keep the arity: {line}");
            assert!(line.contains(",,"), "test metrics must be empty cells: {line}");
        }
    }

    #[test]
    fn final_test_acc_is_the_last_finite_eval_point() {
        let mut sess = StubSession::new();
        sess.finite_evals = 1; // first eval finite, later ones NaN
        let report = train_session(&mut sess, "ember", 300, &cfg(4, 2)).unwrap();
        assert_eq!(report.curve.len(), 2);
        assert!(report.curve[1].test_acc.is_nan(), "late evals are NaN in the curve");
        assert_eq!(report.final_test_acc, 0.6, "report falls back to the last finite point");
        assert!((report.overfit() - (0.5 - 0.6)).abs() < 1e-6);
    }
}
