"""AOT export: lower the L2 programs ONCE to HLO text + manifest.json.

This is the only place Python touches the model after development: it
emits ``artifacts/*.hlo.txt`` (HLO **text**, not ``.serialize()`` — the
image's xla_extension 0.5.1 rejects jax≥0.5's 64-bit-id protos; the text
parser reassigns ids) plus ``artifacts/manifest.json`` describing every
program's inputs/outputs so the rust coordinator can allocate, feed and
checkpoint buffers without Python.

Usage (from ``python/``):

    python -m compile.aot --out ../artifacts                  # core set
    python -m compile.aot --out ../artifacts --set bench-ember
    python -m compile.aot --out ../artifacts \
        --spec task=text,model=hrrformer,preset=small,T=1024,B=4,programs=init+train_step+predict
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import re

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .configs import get_config
from .kernels import hrr, ref

DTYPE_NAMES = {
    np.dtype("float32"): "f32",
    np.dtype("int32"): "i32",
    np.dtype("uint32"): "u32",
}


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see /opt/xla-example)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _keystr(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return ".".join(parts)


def param_specs(cfg):
    """Flattened (name, shape, dtype) list in deterministic tree order."""
    params = jax.eval_shape(lambda k: M.init_params(k, cfg), jax.random.PRNGKey(0))
    leaves, treedef = jax.tree_util.tree_flatten(params)
    named = jax.tree_util.tree_flatten_with_path(params)[0]
    names = [_keystr(p) for p, _ in named]
    return names, leaves, treedef


def _iospec(name, aval):
    return {
        "name": name,
        "shape": [int(s) for s in aval.shape],
        "dtype": DTYPE_NAMES[np.dtype(aval.dtype)],
    }


def _spec_of(x):
    return jax.ShapeDtypeStruct(x.shape, x.dtype)


class Exporter:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        os.makedirs(out_dir, exist_ok=True)
        self.manifest_path = os.path.join(out_dir, "manifest.json")
        if os.path.exists(self.manifest_path):
            with open(self.manifest_path) as f:
                self.manifest = json.load(f)
        else:
            self.manifest = {"programs": {}}

    def save(self):
        with open(self.manifest_path, "w") as f:
            json.dump(self.manifest, f, indent=1, sort_keys=True)

    def emit(self, key: str, fn, in_specs, in_names, meta: dict, force=False):
        fname = f"{key}.hlo.txt"
        fpath = os.path.join(self.out_dir, fname)
        if not force and os.path.exists(fpath) and key in self.manifest["programs"]:
            print(f"  [skip] {key} (exists)")
            return
        lowered = jax.jit(fn).lower(*in_specs)
        out_shape = jax.eval_shape(fn, *in_specs)
        out_leaves = jax.tree_util.tree_leaves(out_shape)
        named_out = jax.tree_util.tree_flatten_with_path(out_shape)[0]
        out_names = [_keystr(p) or f"out{i}" for i, (p, _) in enumerate(named_out)]
        text = to_hlo_text(lowered)
        with open(fpath, "w") as f:
            f.write(text)
        entry = dict(meta)
        entry.update(
            {
                "file": fname,
                "inputs": [_iospec(n, s) for n, s in zip(in_names, in_specs)],
                "outputs": [_iospec(n, s) for n, s in zip(out_names, out_leaves)],
            }
        )
        self.manifest["programs"][key] = entry
        print(f"  [ok]   {key}  ({len(text)//1024} KiB, {len(in_specs)} in / {len(out_leaves)} out)")


def export_model(ex: Exporter, task: str, model_name: str, preset: str,
                 seq_len: int, batch: int, programs, force=False, tag="",
                 **overrides):
    """Export one (task, model, preset[, tag], T, B) program family.

    ``tag`` disambiguates variant configs (e.g. single-layer, narrow-embed
    speed-bench) that would otherwise collide on the manifest key.
    """
    cfg = get_config(task, model_name, preset=preset, seq_len=seq_len, **overrides)
    names, leaves, treedef = param_specs(cfg)
    pspecs = [jax.ShapeDtypeStruct(l.shape, l.dtype) for l in leaves]
    key = f"{task}_{model_name}_{preset}{tag}_T{cfg.seq_len}_B{batch}"
    meta_base = {
        "task": task,
        "model": model_name,
        "preset": preset,
        "seq_len": cfg.seq_len,
        "batch": batch,
        "classes": cfg.classes,
        "vocab": cfg.vocab,
        "layers": cfg.layers,
        "heads": cfg.heads,
        "embed": cfg.embed,
        "config": dataclasses.asdict(cfg),
        "params": [_iospec(n, s) for n, s in zip(names, pspecs)],
    }
    ids_spec = jax.ShapeDtypeStruct((batch, cfg.seq_len), jnp.int32)
    lbl_spec = jax.ShapeDtypeStruct((batch,), jnp.int32)
    step_spec = jax.ShapeDtypeStruct((), jnp.int32)
    seed_spec = jax.ShapeDtypeStruct((), jnp.uint32)
    unflatten = lambda flat: jax.tree_util.tree_unflatten(treedef, flat)
    np_ = len(pspecs)

    if "init" in programs:
        def init_fn(seed):
            return tuple(jax.tree_util.tree_leaves(
                M.init_params(jax.random.PRNGKey(seed), cfg)))
        ex.emit(f"{key}_init", init_fn, [seed_spec], ["seed"],
                {**meta_base, "kind": "init"}, force=force)

    if "train_step" in programs:
        def step_fn(*args):
            p = unflatten(list(args[:np_]))
            m = unflatten(list(args[np_:2 * np_]))
            v = unflatten(list(args[2 * np_:3 * np_]))
            step, ids, labels = args[3 * np_], args[3 * np_ + 1], args[3 * np_ + 2]
            p2, m2, v2, loss, acc = M.train_step(cfg, p, m, v, step, ids, labels)
            return (*jax.tree_util.tree_leaves(p2), *jax.tree_util.tree_leaves(m2),
                    *jax.tree_util.tree_leaves(v2), loss, acc)
        in_specs = pspecs * 3 + [step_spec, ids_spec, lbl_spec]
        in_names = ([f"params.{n}" for n in names] + [f"m.{n}" for n in names]
                    + [f"v.{n}" for n in names] + ["step", "ids", "labels"])
        ex.emit(f"{key}_train_step", step_fn, in_specs, in_names,
                {**meta_base, "kind": "train_step"}, force=force)

    if "predict" in programs:
        def predict_fn(*args):
            p = unflatten(list(args[:np_]))
            return M.logits_fn(p, cfg, args[np_])
        ex.emit(f"{key}_predict", predict_fn, pspecs + [ids_spec],
                [f"params.{n}" for n in names] + ["ids"],
                {**meta_base, "kind": "predict"}, force=force)

    if "eval_step" in programs:
        def eval_fn(*args):
            p = unflatten(list(args[:np_]))
            return M.eval_step(cfg, p, args[np_], args[np_ + 1])
        ex.emit(f"{key}_eval_step", eval_fn, pspecs + [ids_spec, lbl_spec],
                [f"params.{n}" for n in names] + ["ids", "labels"],
                {**meta_base, "kind": "eval_step"}, force=force)

    if "attn_weights" in programs and model_name == "hrrformer":
        def weights_fn(*args):
            # Return logits alongside w so every parameter stays live in
            # the lowered module (XLA prunes unused inputs, which would
            # desync the manifest's input list from the compiled program).
            p = unflatten(list(args[:np_]))
            return M.attn_weights_fn(p, cfg, args[np_]), M.logits_fn(p, cfg, args[np_])
        ex.emit(f"{key}_attn_weights", weights_fn, pspecs + [ids_spec],
                [f"params.{n}" for n in names] + ["ids"],
                {**meta_base, "kind": "attn_weights"}, force=force)


def export_kernel_microbench(ex: Exporter, n: int, t: int, h: int, force=False):
    """Standalone kernel programs for criterion micro-benches (L1 hot path)."""
    spec = jax.ShapeDtypeStruct((1, n, t, h), jnp.float32)
    meta = {"kind": "kernel", "task": "kernel", "model": "kernel",
            "seq_len": t, "batch": n, "heads": n, "embed": h, "preset": "kernel"}

    def hrr_fn(q, k, v):
        return hrr.hrr_attention_pallas(q, k, v)

    def softmax_fn(q, k, v):
        return ref.softmax_attention_ref(q, k, v)

    ex.emit(f"kernel_hrr_N{n}_T{t}_H{h}", hrr_fn, [spec] * 3, ["q", "k", "v"],
            {**meta, "model": "hrr_kernel"}, force=force)
    ex.emit(f"kernel_softmax_N{n}_T{t}_H{h}", softmax_fn, [spec] * 3, ["q", "k", "v"],
            {**meta, "model": "softmax_kernel"}, force=force)


# ---------------------------------------------------------------------------
# Export sets (DESIGN.md §4 experiment index)
# ---------------------------------------------------------------------------

CORE_PROGRAMS = ("init", "train_step", "predict", "eval_step")


def set_core(ex, force):
    """Enough for quickstart, examples, rust integration tests."""
    export_model(ex, "listops", "hrrformer", "small", 512, 8,
                 CORE_PROGRAMS + ("attn_weights",), force=force)
    export_model(ex, "text", "hrrformer", "small", 1024, 4, CORE_PROGRAMS, force=force)
    export_model(ex, "text", "transformer", "small", 1024, 4, CORE_PROGRAMS, force=force)
    # serving buckets for the router (predict-only, several T)
    for t in (256, 512, 1024):
        export_model(ex, "ember", "hrrformer", "small", t, 8, ("init", "predict"), force=force)
    export_model(ex, "ember", "hrrformer", "small", 1024, 8,
                 ("train_step", "eval_step"), force=force)
    export_kernel_microbench(ex, 4, 1024, 64, force=force)


def set_bench_ember(ex, force):
    """Table 5 / Figs 1,4: accuracy+time vs T for every model."""
    models = ["hrrformer", "transformer", "fnet", "linformer", "performer",
              "linear_transformer", "luna"]
    for t in (256, 512, 1024, 2048, 4096):
        b = max(min(2 ** (13 - int(np.log2(t))), 32), 1)  # scaled-down paper rule
        for m in models:
            if m == "transformer" and t > 2048:
                continue  # OOM analogue documented in bench harness
            export_model(ex, "ember", m, "small", t, b,
                         ("init", "train_step", "eval_step"), force=force)
    # long-tail timing-only (hrrformer & fnet reach much longer T)
    for t in (8192, 16384):
        for m in ("hrrformer", "fnet"):
            export_model(ex, "ember", m, "small", t, 1,
                         ("init", "train_step"), force=force)


def set_bench_lra(ex, force):
    """Table 1 / Fig 8: LRA accuracy for the implemented zoo."""
    models = ["hrrformer", "transformer", "fnet", "linformer", "performer",
              "linear_transformer", "local", "luna"]
    tasks = {"listops": (512, 16), "text": (1024, 8), "retrieval": (1024, 8),
             "image": (1024, 8), "pathfinder": (1024, 8)}
    for task, (t, b) in tasks.items():
        for m in models:
            export_model(ex, task, m, "small", t, b,
                         ("init", "train_step", "eval_step"), force=force)
    # single-layer hrrformer rows of Table 1
    for task, (t, b) in tasks.items():
        export_model(ex, task, "hrrformer", "small", t, b,
                     ("init", "train_step", "eval_step"), tag="1L",
                     layers=1, force=force)


def set_bench_speed(ex, force):
    """Table 4 / Fig 6 protocol: text task, 6 layers, B=4, embed 32/64."""
    models = ["hrrformer", "transformer", "fnet", "linformer", "performer",
              "linear_transformer", "local", "luna"]
    for m in models:
        export_model(ex, "text", m, "small", 1024, 4,
                     ("init", "train_step", "predict"), tag="6L",
                     layers=6, embed=32, mlp_dim=64, heads=2, force=force)
    export_model(ex, "text", "hrrformer", "small", 1024, 4,
                 ("init", "train_step", "predict"), tag="1Lspeed",
                 layers=1, embed=32, mlp_dim=64, heads=2, force=force)


def set_bench_inference(ex, force):
    """Tables 6-7: inference time vs batch size, hrrformer vs transformer."""
    for b in (2, 4, 8, 16, 32):
        for m in ("hrrformer", "transformer"):
            export_model(ex, "text", m, "small", 1024, b, ("init", "predict"), force=force)


def set_bench_weights(ex, force):
    """Figs 5/9/10: image-task attention maps."""
    export_model(ex, "image", "hrrformer", "small", 1024, 8,
                 ("init", "train_step", "eval_step", "attn_weights"), force=force)
    export_model(ex, "image", "hrrformer", "small", 1024, 8,
                 ("init", "train_step", "eval_step", "attn_weights"),
                 tag="1L", layers=1, force=force)


SETS = {
    "core": set_core,
    "bench-ember": set_bench_ember,
    "bench-lra": set_bench_lra,
    "bench-speed": set_bench_speed,
    "bench-inference": set_bench_inference,
    "bench-weights": set_bench_weights,
}


def parse_spec(spec: str) -> dict:
    kv = dict(item.split("=", 1) for item in spec.split(","))
    return {
        "task": kv["task"],
        "model_name": kv["model"],
        "preset": kv.get("preset", "small"),
        "seq_len": int(kv.get("T", 0)) or None,
        "batch": int(kv.get("B", 4)),
        "programs": tuple(kv.get("programs", "init+train_step+predict").split("+")),
        **{k: int(v) for k, v in kv.items()
           if k in ("layers", "heads", "embed", "mlp_dim")},
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--set", action="append", default=[], choices=list(SETS),
                    dest="sets")
    ap.add_argument("--spec", action="append", default=[])
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    ex = Exporter(args.out)
    sets = args.sets or (["core"] if not args.spec else [])
    for s in sets:
        print(f"== exporting set: {s}")
        SETS[s](ex, args.force)
        ex.save()
    for spec in args.spec:
        kw = parse_spec(spec)
        seq = kw.pop("seq_len")
        export_model(ex, kw.pop("task"), kw.pop("model_name"), kw.pop("preset"),
                     seq, kw.pop("batch"), kw.pop("programs"), force=args.force, **kw)
        ex.save()
    ex.save()
    print(f"manifest: {ex.manifest_path} ({len(ex.manifest['programs'])} programs)")


if __name__ == "__main__":
    main()
