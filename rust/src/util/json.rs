//! Minimal JSON parser/serializer (the offline build has no serde).
//!
//! Supports the full JSON grammar minus exotic number forms; good enough
//! for `artifacts/manifest.json`, the metrics emitters, and — since the
//! HTTP front door landed — untrusted request bodies off the wire.
//! Strings are unescaped for the common escapes
//! (`\" \\ \/ \n \t \r \b \f \uXXXX`), with surrogate pairs combined
//! per RFC 8259 and lone surrogates rejected.
//!
//! Hardening invariants (each pinned by a regression test):
//!
//! * nesting depth is capped at [`MAX_DEPTH`] — a `[[[[…` payload
//!   returns [`JsonErrorKind::TooDeep`] instead of overflowing the
//!   parsing thread's stack (a remote DoS once network-facing);
//! * numbers that overflow f64 (`1e999`) are rejected as
//!   [`JsonErrorKind::NonFinite`] instead of parsing to infinity and
//!   re-serializing as `null`;
//! * the integer accessors ([`Json::as_i64`]/[`Json::as_usize`]) return
//!   `None` for non-integral, out-of-range or non-finite values instead
//!   of silently saturating (`-1` → 0, `NaN` → 0).

use std::collections::BTreeMap;
use std::fmt;

/// Maximum container nesting the parser accepts. Deep enough for any
/// real manifest/request document, shallow enough that the recursive
/// descent can never exhaust a thread stack (each level is one small
/// frame; default Rust stacks hold tens of thousands).
pub const MAX_DEPTH: usize = 128;

/// Largest magnitude an f64 can represent exactly as an integer (2^53).
/// Beyond it, adjacent integers collapse, so "the integer this JSON
/// number holds" is no longer well-defined.
const MAX_SAFE_INT: f64 = 9_007_199_254_740_992.0;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// What class of failure a [`JsonError`] is — matchable, so callers
/// (e.g. the HTTP layer) can distinguish hostile-input rejections from
/// plain syntax errors without parsing messages.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JsonErrorKind {
    /// Malformed input: bad token, bad escape, trailing data, …
    Syntax,
    /// Container nesting exceeded [`MAX_DEPTH`].
    TooDeep,
    /// A number literal overflowed f64 (would parse to ±inf).
    NonFinite,
    /// A `\uXXXX` escape formed a lone/ill-formed UTF-16 surrogate.
    BadSurrogate,
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
    pub kind: JsonErrorKind,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    /// Parse directly from a byte slice (e.g. an HTTP body still sitting
    /// in the connection's read buffer) — validates UTF-8 in place, no
    /// copy of the input is ever made.
    pub fn parse_bytes(b: &[u8]) -> Result<Json, JsonError> {
        let s = std::str::from_utf8(b).map_err(|e| JsonError {
            pos: e.valid_up_to(),
            msg: "invalid utf-8".to_string(),
            kind: JsonErrorKind::Syntax,
        })?;
        Json::parse(s)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The value as a usize — `None` unless it is a number that holds an
    /// exact non-negative integer in range. A malformed `seq_len` of
    /// `-1`, `1.5` or `NaN` must surface as absent, not silently become
    /// a valid-looking 0 (the old `as` saturation).
    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }

    /// The value as an i64 — `None` unless it is a number that is
    /// finite, integral, and within the exactly-representable ±2^53
    /// range (beyond it f64 cannot name a specific integer).
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && n.abs() <= MAX_SAFE_INT => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
    /// Current container nesting level, checked against [`MAX_DEPTH`].
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        self.err_kind(JsonErrorKind::Syntax, msg)
    }

    fn err_kind(&self, kind: JsonErrorKind, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string(), kind }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        let n = s.parse::<f64>().map_err(|_| self.err("bad number"))?;
        // `1e999` parses to +inf without complaint; serialized back it
        // would become `null` (the writer's non-finite rule) — a
        // silently morphing value. Reject it at the door instead.
        // (Underflow to 0.0/subnormals is fine: still finite.)
        if !n.is_finite() {
            return Err(self.err_kind(JsonErrorKind::NonFinite, "number overflows f64"));
        }
        Ok(Json::Num(n))
    }

    /// Read exactly four hex digits of a `\uXXXX` escape. Every byte is
    /// checked (`from_str_radix` alone would accept a leading `+`).
    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.b.len() {
            return Err(self.err("bad \\u escape"));
        }
        let quad = &self.b[self.pos..self.pos + 4];
        if !quad.iter().all(|b| b.is_ascii_hexdigit()) {
            return Err(self.err("bad \\u escape"));
        }
        let cp = u32::from_str_radix(std::str::from_utf8(quad).unwrap(), 16).unwrap();
        self.pos += 4;
        Ok(cp)
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        if (0xDC00..=0xDFFF).contains(&cp) {
                            // a low surrogate with no preceding high half
                            return Err(self.err_kind(
                                JsonErrorKind::BadSurrogate,
                                "lone low surrogate",
                            ));
                        } else if (0xD800..=0xDBFF).contains(&cp) {
                            // UTF-16 surrogate pair: the escape pair
                            // D83D,DE00 is one character (U+1F600 😀),
                            // not two replacement chars. RFC 8259 §7:
                            // the pair combines; anything else is
                            // ill-formed.
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err_kind(
                                    JsonErrorKind::BadSurrogate,
                                    "unpaired high surrogate",
                                ));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..=0xDFFF).contains(&lo) {
                                return Err(self.err_kind(
                                    JsonErrorKind::BadSurrogate,
                                    "high surrogate not followed by low surrogate",
                                ));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            // combined surrogate pairs always land in
                            // U+10000..=U+10FFFF — valid scalar values
                            out.push(char::from_u32(c).unwrap_or('\u{fffd}'));
                        } else {
                            // non-surrogate BMP code points are all valid
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // multi-byte UTF-8: copy the remaining continuation bytes
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.pos - 1;
                    self.pos = (start + len).min(self.b.len());
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err_kind(JsonErrorKind::TooDeep, "nesting exceeds MAX_DEPTH"));
        }
        let r = self.array_inner();
        self.depth -= 1;
        r
    }

    fn array_inner(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err_kind(JsonErrorKind::TooDeep, "nesting exceeds MAX_DEPTH"));
        }
        let r = self.object_inner();
        self.depth -= 1;
        r
    }

    fn object_inner(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if u32::from(c) < 0x20 => out.push_str(&format!("\\u{:04x}", u32::from(c))),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_json(self, &mut s);
        f.write_str(&s)
    }
}

pub fn write_json(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if !n.is_finite() {
                // JSON has no NaN/inf literal: a bare `NaN` token makes
                // the whole document unparseable, silently corrupting
                // trajectory files. Emit the one lossless stand-in.
                out.push_str("null");
            } else if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{}", n));
            }
        }
        Json::Str(s) => escape(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, v) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(v, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, v)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape(k, out);
                out.push(':');
                write_json(v, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_doc() {
        let doc = r#"{"programs": {"a_b": {"file": "a.hlo.txt", "seq_len": 1024,
            "inputs": [{"name":"seed","shape":[],"dtype":"u32"}], "ok": true, "x": null}}}"#;
        let j = Json::parse(doc).unwrap();
        let prog = j.get("programs").unwrap().get("a_b").unwrap();
        assert_eq!(prog.get("file").unwrap().as_str(), Some("a.hlo.txt"));
        assert_eq!(prog.get("seq_len").unwrap().as_usize(), Some(1024));
        let ins = prog.get("inputs").unwrap().as_arr().unwrap();
        assert_eq!(ins[0].get("dtype").unwrap().as_str(), Some("u32"));
        assert_eq!(prog.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(prog.get("x"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let doc = r#"{"a":[1,2.5,-3],"b":"hi\nthere","c":{"d":false}}"#;
        let j = Json::parse(doc).unwrap();
        let s = j.to_string();
        assert_eq!(Json::parse(&s).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{bad}").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{} extra").is_err());
    }

    #[test]
    fn non_finite_numbers_serialize_as_null_not_invalid_json() {
        for v in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            assert_eq!(Json::Num(v).to_string(), "null");
        }
        // a document carrying a degenerate number must stay parseable
        let mut m = BTreeMap::new();
        m.insert("speedup".to_string(), Json::Num(f64::NAN));
        m.insert("ok".to_string(), Json::Num(2.5));
        let doc = Json::Obj(m).to_string();
        let parsed = Json::parse(&doc).expect("serializer must never emit invalid JSON");
        assert_eq!(parsed.get("speedup"), Some(&Json::Null));
        assert_eq!(parsed.get("ok").and_then(Json::as_f64), Some(2.5));
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""café →""#).unwrap();
        assert_eq!(j.as_str(), Some("café →"));
    }

    /// A `[[[[…` payload must return a typed error, not recurse until
    /// the parsing thread's stack overflows (remote DoS once the parser
    /// faces the network). 1M levels would need ~1M frames unguarded.
    #[test]
    fn deep_nesting_returns_typed_error_not_stack_overflow() {
        for open in ['[', '{'] {
            let deep: String = std::iter::repeat(open).take(1_000_000).collect();
            let err = Json::parse(&deep).unwrap_err();
            assert_eq!(err.kind, JsonErrorKind::TooDeep, "payload {open}…");
        }
        // mixed nesting trips the same cap
        let mixed: String =
            std::iter::repeat(r#"{"a":["#).take(MAX_DEPTH).collect::<String>();
        assert_eq!(Json::parse(&mixed).unwrap_err().kind, JsonErrorKind::TooDeep);
    }

    /// Nesting at exactly the cap still parses — the cap bounds the
    /// stack, it doesn't shrink the accepted grammar below real docs.
    #[test]
    fn nesting_at_cap_is_accepted() {
        let doc = format!(
            "{}1{}",
            "[".repeat(MAX_DEPTH),
            "]".repeat(MAX_DEPTH)
        );
        assert!(Json::parse(&doc).is_ok());
        let over = format!(
            "{}1{}",
            "[".repeat(MAX_DEPTH + 1),
            "]".repeat(MAX_DEPTH + 1)
        );
        assert_eq!(Json::parse(&over).unwrap_err().kind, JsonErrorKind::TooDeep);
    }

    /// Surrogate pairs combine into one scalar (RFC 8259 §7); the old
    /// code decoded each half to U+FFFD, so an emoji round-tripped as
    /// two replacement characters.
    #[test]
    fn surrogate_pairs_combine() {
        // the escaped pair D83D,DE00 must decode to one U+1F600, not
        // two U+FFFD replacement characters
        let j = Json::parse("\"\\uD83D\\uDE00\"").unwrap();
        assert_eq!(j.as_str(), Some("\u{1F600}"));
        // BMP escapes unaffected
        assert_eq!(Json::parse("\"\\u0041\\u00e9\"").unwrap().as_str(), Some("A\u{e9}"));
        // pair embedded in a longer string
        let j = Json::parse("\"x\\uD83D\\uDE00y\"").unwrap();
        assert_eq!(j.as_str(), Some("x\u{1F600}y"));
        // raw (unescaped) UTF-8 astral chars keep working too
        assert_eq!(Json::parse("\"\u{1F600}\"").unwrap().as_str(), Some("\u{1F600}"));
        // and survive a serialize→parse round trip
        let doc = Json::Str("x\u{1F600}".to_string()).to_string();
        assert_eq!(Json::parse(&doc).unwrap().as_str(), Some("x\u{1F600}"));
    }

    #[test]
    fn lone_surrogates_rejected() {
        for doc in [
            "\"\\uD83D\"",         // lone high at end of string
            "\"\\uD83Dx\"",        // high followed by a plain char
            "\"\\uD83D\\u0041\"",  // high followed by a non-low escape
            "\"\\uDE00\"",         // lone low
            "\"\\uDE00\\uD83D\"",  // reversed pair
        ] {
            let err = Json::parse(doc).unwrap_err();
            assert_eq!(err.kind, JsonErrorKind::BadSurrogate, "doc {doc}");
        }
    }

    /// `1e999` used to parse to +inf and then re-serialize as `null` —
    /// a value that silently morphs across one round trip. Now it is
    /// rejected at parse time with a typed error.
    #[test]
    fn overflow_numbers_rejected_at_parse() {
        for doc in ["1e999", "-1e999", "[1e999]", "1e400"] {
            let err = Json::parse(doc).unwrap_err();
            assert_eq!(err.kind, JsonErrorKind::NonFinite, "doc {doc}");
        }
        // underflow stays finite (0.0) and is accepted
        assert_eq!(Json::parse("1e-999").unwrap().as_f64(), Some(0.0));
    }

    /// Integer accessors must reject what is not exactly an in-range
    /// integer — `-1` silently became `0usize` before, so a malformed
    /// `seq_len` looked valid.
    #[test]
    fn integer_accessors_reject_non_integral_and_out_of_range() {
        assert_eq!(Json::parse("-1").unwrap().as_usize(), None);
        assert_eq!(Json::parse("1.5").unwrap().as_usize(), None);
        assert_eq!(Json::parse("1.5").unwrap().as_i64(), None);
        assert_eq!(Json::Num(f64::NAN).as_i64(), None);
        assert_eq!(Json::Num(f64::NAN).as_usize(), None);
        assert_eq!(Json::Num(f64::INFINITY).as_i64(), None);
        // beyond 2^53 f64 cannot name a specific integer
        assert_eq!(Json::parse("9007199254740994").unwrap().as_i64(), None);
        // in-range exact integers still work
        assert_eq!(Json::parse("-1").unwrap().as_i64(), Some(-1));
        assert_eq!(Json::parse("0").unwrap().as_usize(), Some(0));
        assert_eq!(Json::parse("1024").unwrap().as_usize(), Some(1024));
        assert_eq!(Json::parse("1e3").unwrap().as_usize(), Some(1000));
        // non-numbers are still None, not a panic
        assert_eq!(Json::parse("\"7\"").unwrap().as_usize(), None);
    }

    #[test]
    fn parse_bytes_is_parse_over_a_slice() {
        let j = Json::parse_bytes(br#"{"ids":[1,2,3]}"#).unwrap();
        assert_eq!(j.get("ids").unwrap().as_arr().unwrap().len(), 3);
        // invalid UTF-8 is a syntax error at the offending byte
        let err = Json::parse_bytes(b"\"ab\xff\"").unwrap_err();
        assert_eq!(err.kind, JsonErrorKind::Syntax);
    }

    #[test]
    fn hex_escape_rejects_sloppy_digits() {
        // from_str_radix would accept a leading '+'; the lexer must not
        assert!(Json::parse(r#""\u+0ff""#).is_err());
        assert!(Json::parse(r#""\u00g1""#).is_err());
    }
}
