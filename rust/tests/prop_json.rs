//! Fuzz-style property tests for the hardened `util::json` parser —
//! the parser now sits on the network (`POST /classify` bodies go
//! through it verbatim), so its invariants are security properties:
//!
//! * **total**: any input returns `Ok` or a typed `JsonError` — never a
//!   panic, never a stack overflow (depth-capped recursion);
//! * **round-trip**: serialize → parse is the identity on every value
//!   the serializer can emit;
//! * **strict**: escapes decode per RFC 8259 (surrogate pairs combine,
//!   lone surrogates reject) and numbers never become ±inf.

use hrrformer::util::json::{Json, JsonErrorKind, MAX_DEPTH};
use hrrformer::util::prop::forall;
use hrrformer::util::rng::Rng;

/// Characters chosen to stress the escape paths: quotes, backslashes,
/// control characters, multi-byte UTF-8, and astral-plane codepoints
/// (which serialize/parse through surrogate handling in `\u` form).
const HOSTILE_CHARS: &[char] =
    &['a', 'Z', '"', '\\', '/', '\n', '\t', '\r', '\u{1}', '\u{1f}', 'é', '中', '😀', '𝕏', ' '];

fn gen_string(rng: &mut Rng) -> String {
    (0..rng.usize_below(12)).map(|_| *rng.choose(HOSTILE_CHARS)).collect()
}

fn gen_num(rng: &mut Rng) -> f64 {
    match rng.usize_below(4) {
        0 => rng.range(-1_000_000, 1_000_000) as f64,
        1 => rng.range(-1000, 1000) as f64 / 8.0, // exact binary fractions
        2 => rng.f64() * 1e12 - 5e11,
        _ => rng.range(-9_007_199_254_740_992, 9_007_199_254_740_991) as f64,
    }
}

fn gen_value(rng: &mut Rng, depth: usize) -> Json {
    let pick = if depth == 0 { rng.usize_below(4) } else { rng.usize_below(6) };
    match pick {
        0 => Json::Null,
        1 => Json::Bool(rng.bool(0.5)),
        2 => Json::Num(gen_num(rng)),
        3 => Json::Str(gen_string(rng)),
        4 => Json::Arr((0..rng.usize_below(4)).map(|_| gen_value(rng, depth - 1)).collect()),
        _ => Json::Obj(
            (0..rng.usize_below(4))
                .map(|i| (format!("k{i}_{}", gen_string(rng)), gen_value(rng, depth - 1)))
                .collect(),
        ),
    }
}

#[test]
fn serialize_parse_roundtrip_is_identity() {
    forall(300, 0xD0C5, |rng| {
        let v = gen_value(rng, 4);
        let text = v.to_string();
        let back = Json::parse(&text)
            .unwrap_or_else(|e| panic!("serializer emitted unparseable text {text:?}: {e}"));
        assert_eq!(back, v, "roundtrip diverged for {text:?}");
        // parse_bytes is the same parser over a slice
        assert_eq!(Json::parse_bytes(text.as_bytes()).unwrap(), v);
    });
}

#[test]
fn hostile_strings_roundtrip_through_escaping() {
    forall(300, 0xE5CA, |rng| {
        let s: String = (0..rng.usize_below(40)).map(|_| *rng.choose(HOSTILE_CHARS)).collect();
        let v = Json::Str(s.clone());
        let parsed = Json::parse(&v.to_string()).expect("escaped string must parse");
        assert_eq!(parsed.as_str(), Some(s.as_str()));
    });
}

/// Random bytes from a JSON-ish alphabet reach deep into the parser;
/// whatever they are, the parser must return — `Ok` or typed `Err` —
/// without panicking (the harness converts panics into failures).
#[test]
fn garbage_never_panics() {
    const ALPHABET: &[u8] = b"{}[]\",:0123456789.eE+-truefalsnl\\u \t\n\x00\xff\xc3";
    forall(500, 0x6A5B, |rng| {
        let bytes: Vec<u8> =
            (0..rng.usize_below(64)).map(|_| *rng.choose(ALPHABET)).collect();
        let _ = Json::parse_bytes(&bytes);
    });
}

/// Mutating one byte of a valid document must never panic either —
/// this walks the parser into states pure garbage rarely reaches.
#[test]
fn mutated_valid_documents_never_panic() {
    forall(300, 0xF1B0, |rng| {
        let mut bytes = gen_value(rng, 3).to_string().into_bytes();
        if bytes.is_empty() {
            return;
        }
        let i = rng.usize_below(bytes.len());
        bytes[i] = bytes[i].wrapping_add(1 + rng.next_u64() as u8 % 255);
        let _ = Json::parse_bytes(&bytes);
    });
}

/// Nesting up to MAX_DEPTH parses; anything beyond fails with the
/// typed `TooDeep` error rather than exhausting the thread's stack.
#[test]
fn nesting_depth_is_capped_not_crashed() {
    forall(40, 0xDEEB, |rng| {
        let depth = 1 + rng.usize_below(MAX_DEPTH + 64);
        let doc = format!("{}1{}", "[".repeat(depth), "]".repeat(depth));
        match Json::parse(&doc) {
            Ok(_) => assert!(depth <= MAX_DEPTH, "depth {depth} should have been rejected"),
            Err(e) => {
                assert!(depth > MAX_DEPTH, "depth {depth} should have parsed: {e}");
                assert_eq!(e.kind, JsonErrorKind::TooDeep);
            }
        }
    });
}

/// Every random *sibling-heavy* document parses regardless of width —
/// the cap is on nesting, not size.
#[test]
fn wide_documents_are_not_depth_limited() {
    forall(30, 0x71DE, |rng| {
        let n = 1 + rng.usize_below(2000);
        let doc = format!("[{}]", vec!["0"; n].join(","));
        let arr = Json::parse(&doc).expect("wide array must parse");
        assert_eq!(arr.as_arr().map(|a| a.len()), Some(n));
    });
}

/// Number hardening: overflowing literals fail typed (`NonFinite`),
/// and integer accessors never saturate.
#[test]
fn numbers_stay_finite_and_integers_stay_exact() {
    forall(200, 0x1E99, |rng| {
        // a literal guaranteed to overflow f64
        let exp = 400 + rng.usize_below(600);
        let doc = format!("[1e{exp}]");
        let err = Json::parse(&doc).expect_err("overflowing literal must fail");
        assert_eq!(err.kind, JsonErrorKind::NonFinite);

        // in-range integers roundtrip exactly through as_i64
        let n = rng.range(-9_007_199_254_740_992, 9_007_199_254_740_991);
        let parsed = Json::parse(&format!("{n}")).unwrap();
        assert_eq!(parsed.as_i64(), Some(n));
        // non-integral values are rejected by the integer accessors
        let frac = Json::parse("3.5").unwrap();
        assert_eq!(frac.as_i64(), None);
        assert_eq!(frac.as_usize(), None);
    });
}
