"""The paper's contribution: multi-head HRR self-attention (§3, Fig 2-3).

QKV projections are bias-free dense layers (paper Appendix A), heads are
split exactly as in the standard Transformer, and the mixing itself is
the L1 kernel (``kernels.hrr.hrr_attention``) — Pallas forward with the
oracle-derived backward — or the pure-jnp reference, selected by
``cfg.hrr_impl``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import layers
from ..kernels import hrr, ref


def init(key, cfg):
    kq, kk, kv, ko = jax.random.split(key, 4)
    d = cfg.embed
    return {
        "query": layers.dense_init(kq, d, d, use_bias=False),
        "key": layers.dense_init(kk, d, d, use_bias=False),
        "value": layers.dense_init(kv, d, d, use_bias=False),
        "output": layers.dense_init(ko, d, d, use_bias=False),
    }


def _attend(params, cfg, x, mask):
    q = layers.split_heads(layers.dense(params["query"], x), cfg.heads)
    k = layers.split_heads(layers.dense(params["key"], x), cfg.heads)
    v = layers.split_heads(layers.dense(params["value"], x), cfg.heads)
    if cfg.hrr_impl == "pallas":
        a = hrr.hrr_attention_scores(q, k, v, mask=mask, block_t=cfg.hrr_block_t)
    else:
        m = None
        if mask is not None:
            b, nh, t, _ = q.shape
            m = jnp.broadcast_to(mask[:, None, :], (b, nh, t))
        a = ref.hrr_attention_scores_ref(q, k, v, mask=m)
    if mask is not None:
        a = a + (1.0 - mask[:, None, :, None]) * (-1e9)
    w = jax.nn.softmax(a, axis=-2)  # (B, h, T, 1) — Eq. 4 cleanup
    return w, v


def apply(params, cfg, x, mask, *, rng=None, deterministic=True):
    w, v = _attend(params, cfg, x, mask)
    out = layers.merge_heads(w * v)
    return layers.dense(params["output"], out)


def apply_with_weights(params, cfg, x, mask):
    """Returns (output, w) where w: (B, h, T) — the Fig 5/9 heat-maps."""
    w, v = _attend(params, cfg, x, mask)
    out = layers.dense(params["output"], layers.merge_heads(w * v))
    return out, w[..., 0]
