//! Tables 6 & 7 — inference timing.
//!
//! Table 6: Hrrformer vs Transformer single block, inference time and
//! memory across batch sizes 2..32 on the text task.
//! Table 7: all 6-layer models, total time / examples-per-second /
//! memory for a fixed evaluation set.

use anyhow::Result;

use crate::bench::results_dir;
use crate::data::{batch::BatchStream, by_task, Split};
use crate::model::PredictSession;
use crate::runtime::{Manifest, ProgramSpec, Runtime};
use crate::util::table::Table;

pub struct InferBenchCfg {
    pub examples: usize,
    pub seed: u64,
    /// run the batch-size sweep (Table 6) instead of the model sweep (Table 7)
    pub sweep_batch: bool,
}

impl Default for InferBenchCfg {
    fn default() -> Self {
        InferBenchCfg { examples: 128, seed: 0, sweep_batch: false }
    }
}

#[derive(Debug, Clone)]
pub struct InferRow {
    pub model: String,
    pub batch: usize,
    pub layers: usize,
    pub secs: f64,
    pub examples_per_sec: f64,
    pub rss_mib: f64,
}

fn time_predict(
    rt: &Runtime,
    manifest: &Manifest,
    spec: &ProgramSpec,
    examples: usize,
    seed: u64,
) -> Result<InferRow> {
    let base = spec.key.trim_end_matches("_predict").to_string();
    let sess = PredictSession::create(rt, manifest, &base, seed as u32)?;
    let ds = by_task(&spec.task, spec.seq_len).unwrap();
    let mut stream = BatchStream::new(ds.as_ref(), Split::Test, seed, spec.batch, spec.seq_len);
    // warm-up execution (excluded, like the paper excludes compile)
    let warm = stream.next_batch();
    sess.predict(&warm.ids)?;
    let n_batches = (examples + spec.batch - 1) / spec.batch;
    let batches: Vec<_> = (0..n_batches).map(|_| stream.next_batch()).collect();
    let t0 = std::time::Instant::now();
    for b in &batches {
        sess.predict(&b.ids)?;
    }
    let secs = t0.elapsed().as_secs_f64();
    Ok(InferRow {
        model: spec.model.clone(),
        batch: spec.batch,
        layers: spec.layers,
        secs,
        examples_per_sec: (n_batches * spec.batch) as f64 / secs,
        rss_mib: crate::util::rss_mib(),
    })
}

pub fn run(rt: &Runtime, manifest: &Manifest, cfg: &InferBenchCfg) -> Result<Vec<InferRow>> {
    let mut rows = Vec::new();

    if cfg.sweep_batch {
        // Table 6: B sweep for hrrformer + transformer (default layers).
        let mut specs: Vec<&ProgramSpec> = manifest.select(|p| {
            p.task == "text"
                && p.kind == "predict"
                && (p.model == "hrrformer" || p.model == "transformer")
                && p.embed != 32 // exclude the 6-layer speed-bench variants
        });
        anyhow::ensure!(!specs.is_empty(), "no inference artifacts — run `make artifacts-inference`");
        specs.sort_by_key(|p| (p.model.clone(), p.batch));
        for spec in specs {
            match time_predict(rt, manifest, spec, cfg.examples, cfg.seed) {
                Ok(r) => {
                    eprintln!(
                        "[infer] {:<12} B={:<3} {:.2}s ({:.1} ex/s)",
                        r.model, r.batch, r.secs, r.examples_per_sec
                    );
                    rows.push(r);
                }
                Err(e) => eprintln!("[infer] {} B={} FAILED: {e:#}", spec.model, spec.batch),
            }
        }
        let mut t = Table::new(
            "Table 6 — inference time vs batch size (text task)",
            &["Batch", "Hrrformer time (s)", "Transformer time (s)"],
        );
        let mut batches: Vec<usize> = rows.iter().map(|r| r.batch).collect();
        batches.sort();
        batches.dedup();
        for b in batches {
            let get = |m: &str| {
                rows.iter()
                    .find(|r| r.model == m && r.batch == b)
                    .map(|r| format!("{:.2}", r.secs))
                    .unwrap_or_else(|| "-".into())
            };
            t.row(vec![b.to_string(), get("hrrformer"), get("transformer")]);
        }
        t.print();
    } else {
        // Table 7: every 6-layer model (speed-bench artifacts have predict).
        let mut specs: Vec<&ProgramSpec> = manifest
            .select(|p| p.task == "text" && p.kind == "predict" && p.embed == 32);
        anyhow::ensure!(!specs.is_empty(), "no 6-layer predict artifacts — run `make artifacts-speed`");
        specs.sort_by_key(|p| (p.model.clone(), std::cmp::Reverse(p.layers)));
        for spec in specs {
            match time_predict(rt, manifest, spec, cfg.examples, cfg.seed) {
                Ok(r) => {
                    eprintln!(
                        "[infer] {:<18} L={} {:.2}s ({:.1} ex/s)",
                        r.model, r.layers, r.secs, r.examples_per_sec
                    );
                    rows.push(r);
                }
                Err(e) => eprintln!("[infer] {} FAILED: {e:#}", spec.model),
            }
        }
        let mut t = Table::new(
            "Table 7 — inference time, all models (text task, 6 layers; * = 1 layer)",
            &["Model", "Time (s)", "Examples/s", "RSS (MiB)"],
        );
        let mut sorted: Vec<&InferRow> = rows.iter().collect();
        sorted.sort_by(|a, b| b.secs.partial_cmp(&a.secs).unwrap());
        for r in sorted {
            let name = if r.layers == 1 { format!("{}*", r.model) } else { r.model.clone() };
            t.row(vec![
                name,
                format!("{:.2}", r.secs),
                format!("{:.1}", r.examples_per_sec),
                format!("{:.0}", r.rss_mib),
            ]);
        }
        t.print();
    }

    let mut csv = String::from("model,layers,batch,secs,examples_per_sec,rss_mib\n");
    for r in &rows {
        csv.push_str(&format!(
            "{},{},{},{:.3},{:.2},{:.0}\n",
            r.model, r.layers, r.batch, r.secs, r.examples_per_sec, r.rss_mib
        ));
    }
    let name = if cfg.sweep_batch { "inference_batch.csv" } else { "inference_models.csv" };
    let path = results_dir().join(name);
    let _ = std::fs::write(&path, csv);
    eprintln!("[infer] data → {}", path.display());
    Ok(rows)
}
