//! Stream lifecycle management: many concurrent open streams, each
//! with O(H) carried model state, bounded in-memory buffering, and
//! idle-timeout eviction.
//!
//! A [`StreamRegistry`] owns one [`NativeSession`] and runs every chunk
//! of model compute through the engine's [`RowScheduler`] seam — when
//! the engine installs its shared [`crate::util::pool::WorkerPool`],
//! stream compute occupies one worker slot per chunk and therefore
//! shares the engine-wide worker budget with batch traffic instead of
//! spawning threads of its own.
//!
//! Memory discipline per open stream:
//!
//! * model state — [`StreamState`], O(H) (asserted independent of T by
//!   the integration tests);
//! * token buffer — at most `chunk_cap − 1` pending tokens; full chunks
//!   are folded into pass-0 state immediately and appended to an
//!   on-disk spool for the replay passes;
//! * nothing else. No (B, T) tensor is ever materialized.

use std::collections::{HashMap, VecDeque};
use std::fmt;
use std::path::PathBuf;
use std::time::{Duration, Instant};

use crate::hrr::{NativeSession, RowScheduler, StreamState, StreamWorkspace};
use crate::util::pool::Task;

use super::source::{ChunkSource, SpoolWriter};
use super::{argmax, tokenize_bytes};

/// How many retired stream ids (finished or evicted) the registry
/// remembers so late appends get a precise error instead of a generic
/// "unknown stream".
const RETIRED_CAP: usize = 256;

/// Registry tuning knobs. Construct with [`StreamConfig::new`] and
/// override fields as needed.
#[derive(Clone, Debug)]
pub struct StreamConfig {
    /// Tokens folded into the model per scheduler dispatch. Also the
    /// bound on per-stream pending buffering.
    pub chunk_cap: usize,
    /// Streams idle longer than this are evicted by
    /// [`StreamRegistry::sweep_idle`].
    pub idle_timeout: Duration,
    /// Directory for per-stream replay spools (created on demand).
    pub spool_dir: PathBuf,
    /// Hard cap on concurrently open streams.
    pub max_streams: usize,
}

impl StreamConfig {
    pub fn new(spool_dir: impl Into<PathBuf>) -> StreamConfig {
        StreamConfig {
            chunk_cap: 4096,
            idle_timeout: Duration::from_secs(300),
            spool_dir: spool_dir.into(),
            max_streams: 64,
        }
    }
}

/// Typed stream lifecycle errors — the engine maps these onto
/// `EngineError` for clients.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum StreamError {
    /// Id was never issued (or rotated out of the retired record).
    Unknown(u64),
    /// Id was valid but the stream already finished.
    Finished(u64),
    /// Id was valid but the stream was evicted for idleness.
    Evicted(u64),
    /// Registry is at `max_streams` open streams.
    Capacity { open: usize, max: usize },
    /// The bucket's architecture has no chunked streaming forward
    /// (e.g. HGConv's global convolution needs the whole row) — a
    /// client error, not a server fault.
    NotStreamable { arch: String },
    /// Kernel / IO failure underneath the lifecycle layer.
    Internal(String),
}

impl fmt::Display for StreamError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            StreamError::Unknown(id) => write!(f, "unknown stream id {id}"),
            StreamError::Finished(id) => write!(f, "stream {id} already finished"),
            StreamError::Evicted(id) => write!(f, "stream {id} was evicted after idle timeout"),
            StreamError::Capacity { open, max } => {
                write!(f, "stream capacity reached ({open}/{max} open)")
            }
            StreamError::NotStreamable { arch } => {
                write!(f, "architecture '{arch}' does not support streaming")
            }
            StreamError::Internal(msg) => write!(f, "stream internal error: {msg}"),
        }
    }
}

impl std::error::Error for StreamError {}

/// Result of finishing a stream.
#[derive(Clone, Debug)]
pub struct StreamOutcome {
    /// Class logits from the streamed forward — bit-identical to the
    /// whole-row forward on the same (possibly truncated) tokens.
    pub logits: Vec<f32>,
    /// `argmax(logits)` — for EMBER, 1 = malicious.
    pub label: usize,
    /// Tokens actually folded into the model (≤ the bucket's T).
    pub tokens: usize,
    /// Tokens the client appended in total, including any truncated
    /// tail beyond the bucket's T.
    pub appended: usize,
    /// Whether appends past the bucket length were dropped.
    pub truncated: bool,
    /// Heap bytes of the carried per-stream model state at finish time
    /// — O(H), independent of `tokens`.
    pub resident_bytes: usize,
    /// Weight version the stream ran on — pinned at open, so a hot
    /// reload mid-stream never mixes weights within one classification.
    pub model_version: u64,
}

#[derive(Clone, Copy, Debug)]
enum Retired {
    Finished,
    Evicted,
}

struct OpenStream {
    st: StreamState,
    spool: SpoolWriter,
    /// Tokenized but not yet consumed — strictly less than `chunk_cap`
    /// outside of `append` itself.
    pending: Vec<i32>,
    appended: usize,
    truncated: bool,
    last_touch: Instant,
}

/// Open/append/finish over many concurrent streams against one native
/// session. Single-owner by design: the engine gives it a dedicated
/// executor thread and serializes access through a channel, mirroring
/// the per-bucket executors.
pub struct StreamRegistry {
    sess: NativeSession,
    scheduler: RowScheduler,
    cfg: StreamConfig,
    sw: StreamWorkspace,
    /// Chunk staging shared by every stream (one chunk at a time).
    chunk_buf: Vec<i32>,
    streams: HashMap<u64, OpenStream>,
    retired: VecDeque<(u64, Retired)>,
    next_id: u64,
}

/// Run `f` through the scheduler seam: inline for `Sequential` /
/// `Scoped` (one chunk is one unit of work — nothing to fan out), as a
/// single pool task for `Pool` so stream compute books a worker slot
/// from the same budget batch traffic draws on.
fn run_on_scheduler<T, F>(scheduler: &RowScheduler, f: F) -> Result<T, StreamError>
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    match scheduler {
        RowScheduler::Pool(pool) => {
            let mut out = None;
            let task: Task<'_> = Box::new(|| out = Some(f()));
            pool.run(vec![task])
                .map_err(|p| StreamError::Internal(format!("stream worker panicked: {p}")))?;
            out.ok_or_else(|| StreamError::Internal("stream task did not run".into()))
        }
        _ => Ok(f()),
    }
}

fn internal(e: anyhow::Error) -> StreamError {
    StreamError::Internal(format!("{e:#}"))
}

/// Fold one staged chunk into pass-0 state and the replay spool,
/// truncating at the bucket length. Free function so callers can hold
/// disjoint borrows of the registry's fields.
fn consume_pass0_chunk(
    sess: &NativeSession,
    scheduler: &RowScheduler,
    sw: &mut StreamWorkspace,
    s: &mut OpenStream,
    chunk: &[i32],
) -> Result<(), StreamError> {
    let seq_len = sess.cfg().seq_len;
    let room = seq_len.saturating_sub(s.st.tokens());
    let take = chunk.len().min(room);
    if take < chunk.len() {
        s.truncated = true;
    }
    if take == 0 {
        return Ok(());
    }
    let (st, kept) = (&mut s.st, &chunk[..take]);
    run_on_scheduler(scheduler, || sess.stream_consume(st, sw, kept))?.map_err(internal)?;
    s.spool.write_chunk(kept).map_err(internal)?;
    Ok(())
}

impl StreamRegistry {
    pub fn new(
        sess: NativeSession,
        scheduler: RowScheduler,
        cfg: StreamConfig,
    ) -> Result<StreamRegistry, StreamError> {
        // Gate at construction: a registry over a non-streaming
        // architecture could never serve a single stream, so fail when
        // the bucket is stood up, not on the first `open`.
        if !sess.cfg().arch.streamable() {
            return Err(StreamError::NotStreamable { arch: sess.cfg().arch.to_string() });
        }
        if cfg.chunk_cap == 0 {
            return Err(StreamError::Internal("chunk_cap must be ≥ 1".into()));
        }
        std::fs::create_dir_all(&cfg.spool_dir)
            .map_err(|e| StreamError::Internal(format!("create spool dir: {e}")))?;
        let sw = sess.stream_workspace(cfg.chunk_cap);
        Ok(StreamRegistry {
            sess,
            scheduler,
            sw,
            chunk_buf: Vec::with_capacity(cfg.chunk_cap),
            cfg,
            streams: HashMap::new(),
            retired: VecDeque::new(),
            next_id: 1,
        })
    }

    pub fn open_count(&self) -> usize {
        self.streams.len()
    }

    pub fn session(&self) -> &NativeSession {
        &self.sess
    }

    /// Open a new stream: fresh O(H) state + an empty replay spool.
    pub fn open(&mut self) -> Result<u64, StreamError> {
        if self.streams.len() >= self.cfg.max_streams {
            return Err(StreamError::Capacity {
                open: self.streams.len(),
                max: self.cfg.max_streams,
            });
        }
        let id = self.next_id;
        self.next_id += 1;
        let spool = SpoolWriter::create(self.cfg.spool_dir.join(format!("stream_{id}.tok")))
            .map_err(internal)?;
        self.streams.insert(
            id,
            OpenStream {
                st: self.sess.stream_state(),
                spool,
                pending: Vec::new(),
                appended: 0,
                truncated: false,
                last_touch: Instant::now(),
            },
        );
        Ok(id)
    }

    fn missing(&self, id: u64) -> StreamError {
        match self.retired.iter().rev().find(|(r, _)| *r == id) {
            Some((_, Retired::Finished)) => StreamError::Finished(id),
            Some((_, Retired::Evicted)) => StreamError::Evicted(id),
            None => StreamError::Unknown(id),
        }
    }

    fn retire(&mut self, id: u64, why: Retired) {
        if self.retired.len() == RETIRED_CAP {
            self.retired.pop_front();
        }
        self.retired.push_back((id, why));
    }

    /// Append raw bytes to an open stream. Tokens are staged in the
    /// stream's pending buffer; every full chunk is folded into pass-0
    /// state immediately (through the scheduler) and spooled, so the
    /// buffer never holds a full chunk when this returns. Returns the
    /// total tokens appended so far.
    pub fn append(&mut self, id: u64, bytes: &[u8]) -> Result<usize, StreamError> {
        let cap = self.cfg.chunk_cap;
        let s = match self.streams.get_mut(&id) {
            Some(s) => s,
            None => return Err(self.missing(id)),
        };
        s.last_touch = Instant::now();
        tokenize_bytes(bytes, &mut s.pending);
        s.appended += bytes.len();
        while s.pending.len() >= cap {
            self.chunk_buf.clear();
            self.chunk_buf.extend(s.pending.drain(..cap));
            consume_pass0_chunk(&self.sess, &self.scheduler, &mut self.sw, s, &self.chunk_buf)?;
        }
        Ok(s.appended)
    }

    /// Finish a stream: flush the pending tail into pass 0, then replay
    /// the spool for the remaining 3·L passes and classify. The stream
    /// id is retired; the spool is deleted.
    pub fn finish(&mut self, id: u64) -> Result<StreamOutcome, StreamError> {
        let mut s = match self.streams.remove(&id) {
            Some(s) => s,
            None => return Err(self.missing(id)),
        };
        self.retire(id, Retired::Finished);

        // Pending tail is < chunk_cap by the append invariant.
        self.chunk_buf.clear();
        self.chunk_buf.append(&mut s.pending);
        consume_pass0_chunk(&self.sess, &self.scheduler, &mut self.sw, &mut s, &self.chunk_buf)?;

        let OpenStream { mut st, spool, appended, truncated, .. } = s;
        self.sess.stream_end_pass(&mut st).map_err(internal)?;
        let mut reader = spool.into_reader().map_err(internal)?;

        // Replay passes 1..3L+1. One scheduler dispatch per chunk keeps
        // the worker-slot hold time bounded, so long replays interleave
        // with batch traffic instead of monopolizing a worker.
        let (sess, sw, buf) = (&self.sess, &mut self.sw, &mut self.chunk_buf);
        buf.resize(self.cfg.chunk_cap, 0);
        while !st.ready() {
            reader.reset().map_err(internal)?;
            loop {
                let n = reader.next_chunk(buf).map_err(internal)?;
                if n == 0 {
                    break;
                }
                let (st_ref, chunk) = (&mut st, &buf[..n]);
                run_on_scheduler(&self.scheduler, || sess.stream_consume(st_ref, sw, chunk))?
                    .map_err(internal)?;
            }
            sess.stream_end_pass(&mut st).map_err(internal)?;
        }

        let logits = sess.stream_logits(&st).map_err(internal)?;
        Ok(StreamOutcome {
            label: argmax(&logits),
            tokens: st.tokens(),
            appended,
            truncated,
            resident_bytes: st.resident_bytes(),
            model_version: st.model_version(),
            logits,
        })
    }

    /// Evict streams idle longer than the configured timeout. Evicted
    /// ids are remembered so later appends get [`StreamError::Evicted`]
    /// rather than [`StreamError::Unknown`]. Returns the evicted ids.
    pub fn sweep_idle(&mut self) -> Vec<u64> {
        let timeout = self.cfg.idle_timeout;
        // The candidate set comes out of the HashMap in arbitrary
        // order; sort before evicting so the returned ids (and the
        // retire/log order operators see) are deterministic.
        let mut evict: Vec<u64> = self
            // hrrlint: allow(hash-iter-accum) -- sorted below
            .streams
            .iter()
            .filter(|(_, s)| s.last_touch.elapsed() >= timeout)
            .map(|(&id, _)| id)
            .collect();
        evict.sort_unstable();
        for &id in &evict {
            // Dropping the OpenStream drops its SpoolWriter, which
            // unlinks the spool file.
            self.streams.remove(&id);
            self.retire(id, Retired::Evicted);
        }
        evict
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hrr::HrrConfig;
    use crate::runtime::tensor::Tensor;
    use crate::util::pool::WorkerPool;
    use std::sync::Arc;

    fn tiny_session() -> NativeSession {
        let cfg = HrrConfig {
            arch: crate::hrr::Arch::Hrrformer,
            task: "test".into(),
            vocab: 257,
            seq_len: 32,
            batch: 2,
            embed: 16,
            mlp_dim: 32,
            heads: 2,
            layers: 1,
            classes: 2,
            learned_pos: true,
        };
        NativeSession::from_config(cfg, 11).unwrap()
    }

    fn test_cfg(name: &str) -> StreamConfig {
        let mut cfg =
            StreamConfig::new(std::env::temp_dir().join("hrrformer_registry_test").join(name));
        cfg.chunk_cap = 7; // force multi-chunk paths even for tiny streams
        cfg
    }

    fn registry(name: &str, scheduler: RowScheduler) -> StreamRegistry {
        StreamRegistry::new(tiny_session(), scheduler, test_cfg(name)).unwrap()
    }

    #[test]
    fn lifecycle_matches_whole_row_predict_bitwise() {
        for (name, scheduler) in [
            ("seq", RowScheduler::Sequential),
            ("pool", RowScheduler::Pool(Arc::new(WorkerPool::new(2)))),
        ] {
            let mut reg = registry(name, scheduler);
            let bytes: Vec<u8> = (0..32u32).map(|i| (i * 37 % 256) as u8).collect();
            let ids: Vec<i32> = bytes.iter().map(|&b| b as i32 + 1).collect();
            let want = reg.session().predict(&Tensor::i32(vec![1, 32], ids)).unwrap();

            let id = reg.open().unwrap();
            for part in bytes.chunks(5) {
                reg.append(id, part).unwrap();
            }
            let out = reg.finish(id).unwrap();
            assert_eq!(out.logits.as_slice(), want.as_f32().unwrap(), "scheduler {name}");
            assert_eq!(out.tokens, 32);
            assert_eq!(out.appended, 32);
            assert!(!out.truncated);
            assert_eq!(reg.open_count(), 0);
        }
    }

    #[test]
    fn truncation_matches_prefix_prediction() {
        let mut reg = registry("trunc", RowScheduler::Sequential);
        let bytes: Vec<u8> = (0..100u32).map(|i| (i % 251 + 1) as u8).collect();
        let prefix_ids: Vec<i32> = bytes[..32].iter().map(|&b| b as i32 + 1).collect();
        let want = reg.session().predict(&Tensor::i32(vec![1, 32], prefix_ids)).unwrap();

        let id = reg.open().unwrap();
        reg.append(id, &bytes).unwrap();
        let out = reg.finish(id).unwrap();
        assert!(out.truncated);
        assert_eq!(out.tokens, 32);
        assert_eq!(out.appended, 100);
        assert_eq!(out.logits.as_slice(), want.as_f32().unwrap());
    }

    #[test]
    fn lifecycle_errors_are_distinct() {
        let mut reg = registry("errors", RowScheduler::Sequential);
        assert_eq!(reg.append(99, b"x"), Err(StreamError::Unknown(99)));

        let id = reg.open().unwrap();
        reg.append(id, b"abc").unwrap();
        reg.finish(id).unwrap();
        assert_eq!(reg.append(id, b"late"), Err(StreamError::Finished(id)));
        assert!(matches!(reg.finish(id), Err(StreamError::Finished(_))));
    }

    #[test]
    fn idle_streams_are_evicted_with_typed_error() {
        let mut cfg = test_cfg("evict");
        cfg.idle_timeout = Duration::from_millis(0);
        let mut reg = StreamRegistry::new(tiny_session(), RowScheduler::Sequential, cfg).unwrap();
        let id = reg.open().unwrap();
        reg.append(id, b"payload").unwrap();
        let evicted = reg.sweep_idle();
        assert_eq!(evicted, vec![id]);
        assert_eq!(reg.open_count(), 0);
        assert_eq!(reg.append(id, b"x"), Err(StreamError::Evicted(id)));
    }

    #[test]
    fn non_streaming_architectures_are_rejected_at_construction() {
        let cfg = HrrConfig {
            arch: crate::hrr::Arch::HgConv,
            ..tiny_session().cfg().clone()
        };
        let sess = NativeSession::from_config(cfg, 11).unwrap();
        let err = StreamRegistry::new(sess, RowScheduler::Sequential, test_cfg("hgconv"))
            .err()
            .expect("hgconv registry must be refused");
        assert_eq!(err, StreamError::NotStreamable { arch: "hgconv".into() });
        assert!(err.to_string().contains("does not support streaming"));
    }

    #[test]
    fn capacity_is_enforced() {
        let mut cfg = test_cfg("cap");
        cfg.max_streams = 2;
        let mut reg = StreamRegistry::new(tiny_session(), RowScheduler::Sequential, cfg).unwrap();
        reg.open().unwrap();
        reg.open().unwrap();
        assert_eq!(reg.open(), Err(StreamError::Capacity { open: 2, max: 2 }));
    }

    #[test]
    fn resident_state_is_independent_of_stream_length() {
        let mut reg = registry("resident", RowScheduler::Sequential);
        let short = {
            let id = reg.open().unwrap();
            reg.append(id, &[1u8; 8]).unwrap();
            reg.finish(id).unwrap()
        };
        let long = {
            let id = reg.open().unwrap();
            reg.append(id, &[2u8; 1000]).unwrap(); // truncated at T=32
            reg.finish(id).unwrap()
        };
        assert_eq!(short.resident_bytes, long.resident_bytes);
        assert!(short.resident_bytes > 0);
    }
}
