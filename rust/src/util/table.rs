//! Markdown/aligned-text table writer for the bench harness — the paper's
//! tables are regenerated as these.

#[derive(Debug, Default, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn to_markdown(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("\n### {}\n\n", self.title));
        }
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for i in 0..ncol {
                line.push_str(&format!(" {:<w$} |", cells[i], w = widths[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = self.headers.join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.to_markdown());
    }
}

pub fn fmt_f(v: f64, prec: usize) -> String {
    format!("{:.*}", prec, v)
}

pub fn fmt_pct(v: f64) -> String {
    format!("{:.2}%", v * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_shape() {
        let mut t = Table::new("Demo", &["model", "acc"]);
        t.row(vec!["hrrformer".into(), "91.03%".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### Demo"));
        assert!(md.contains("| hrrformer | 91.03% |"));
        assert!(md.lines().filter(|l| l.starts_with('|')).count() == 3);
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn csv() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        assert_eq!(t.to_csv(), "a,b\n1,2\n");
    }
}
