"""Linear Transformer (Katharopoulos et al. 2020): φ(x) = elu(x)+1 kernel.

out_t = φ(q_t)ᵀ (Σ_s φ(k_s) v_sᵀ) / (φ(q_t)ᵀ Σ_s φ(k_s)) — O(T·H'²).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import layers


def init(key, cfg):
    kq, kk, kv, ko = jax.random.split(key, 4)
    d = cfg.embed
    return {
        "query": layers.dense_init(kq, d, d, use_bias=False),
        "key": layers.dense_init(kk, d, d, use_bias=False),
        "value": layers.dense_init(kv, d, d, use_bias=False),
        "output": layers.dense_init(ko, d, d, use_bias=False),
    }


def _phi(x):
    return jax.nn.elu(x) + 1.0


def apply(params, cfg, x, mask, *, rng=None, deterministic=True):
    q = layers.split_heads(layers.dense(params["query"], x), cfg.heads)
    k = layers.split_heads(layers.dense(params["key"], x), cfg.heads)
    v = layers.split_heads(layers.dense(params["value"], x), cfg.heads)
    qf, kf = _phi(q), _phi(k)
    if mask is not None:
        kf = kf * mask[:, None, :, None]
        v = v * mask[:, None, :, None]
    kv = jnp.einsum("bhtm,bhtd->bhmd", kf, v)
    num = jnp.einsum("bhtm,bhmd->bhtd", qf, kv)
    den = jnp.einsum("bhtm,bhm->bht", qf, jnp.sum(kf, axis=2))[..., None]
    out = num / (den + 1e-6)
    return layers.dense(params["output"], layers.merge_heads(out))
