//! hrrlint rule engine: eight project-invariant lints over the token
//! stream from [`super::lexer`].
//!
//! Everything here is token-level and deliberately simple — the rules
//! are tripwires that force a human re-audit, not a type system. Two
//! mechanisms keep them honest:
//!
//! * items under a `#[test]`-like attribute (`#[cfg(test)]`, `#[test]`)
//!   are exempt — but `#[cfg(not(test))]` is real code and is not;
//! * a comment containing `hrrlint: allow(rule-a, rule-b)` suppresses
//!   those rules on its own line and the line below (the audited
//!   escape hatch; every use should say why).
//!
//! Mirrored line-for-line by `python/analysis/hrrlint.py` — keep the
//! two in sync (the parity test pins byte-identical reports).

use std::collections::{BTreeMap, BTreeSet};

use super::lexer::{lex, Token, TokenKind};
use crate::model::artifact::fnv64;

/// The rule identifiers, in documentation order.
pub const RULES: [&str; 8] = [
    "panic-path",
    "wallclock-kernel",
    "hash-iter-accum",
    "f32-accum-kernel",
    "unbounded-channel",
    "narrow-cast-wire",
    "lock-order",
    "debug-macro",
];

/// One lint hit. `hash` is FNV-1a-64 of `rule:file:snippet` — content-
/// keyed so the baseline survives unrelated line shifts.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub rule: String,
    pub snippet: String,
    pub message: String,
    pub hash: String,
    /// Filled in by [`super::baseline::apply_baseline`].
    pub new: bool,
}

pub fn fnv1a64_hex(text: &str) -> String {
    format!("{:016x}", fnv64(text.as_bytes()))
}

// ---------------------------------------------------------------------------
// Scopes (paths are forward-slash, relative to the scan root)
// ---------------------------------------------------------------------------

fn in_panic_scope(path: &str) -> bool {
    ["engine/", "net/", "stream/", "model/", "hrr/"].iter().any(|p| path.starts_with(p))
}

fn in_kernel_scope(path: &str) -> bool {
    ["hrr/common/", "hrr/hrrformer/", "hrr/hgconv/"].iter().any(|p| path.starts_with(p))
}

fn in_channel_scope(path: &str) -> bool {
    ["engine/", "stream/", "net/", "coordinator/"].iter().any(|p| path.starts_with(p))
}

fn in_wire_scope(path: &str) -> bool {
    path.starts_with("net/") || path == "util/json.rs"
}

fn in_lock_scope(path: &str) -> bool {
    path.starts_with("engine/")
}

fn in_debug_scope(path: &str) -> bool {
    !(path == "main.rs" || path.starts_with("bench/") || path.starts_with("bin/"))
}

// ---------------------------------------------------------------------------
// Test-region marking + suppressions
// ---------------------------------------------------------------------------

/// `tokens[i] == '#'`, `tokens[i+1] == '['`. Returns the index of the
/// matching `]` and whether the attribute is test-like (mentions the
/// ident `test` without the ident `not`).
fn scan_attribute(tokens: &[Token], i: usize) -> (usize, bool) {
    let n = tokens.len();
    let mut depth = 0usize;
    let mut has_test = false;
    let mut has_not = false;
    let mut j = i + 1;
    while j < n {
        let t = &tokens[j];
        if t.text == "[" {
            depth += 1;
        } else if t.text == "]" {
            depth -= 1;
            if depth == 0 {
                return (j, has_test && !has_not);
            }
        } else if t.kind == TokenKind::Ident {
            if t.text == "test" {
                has_test = true;
            } else if t.text == "not" {
                has_not = true;
            }
        }
        j += 1;
    }
    (n - 1, false)
}

/// Boolean per token: inside an item guarded by a test-like attribute.
fn mark_test_regions(tokens: &[Token]) -> Vec<bool> {
    let n = tokens.len();
    let mut in_test = vec![false; n];
    let mut i = 0usize;
    while i < n {
        if tokens[i].text == "#" && i + 1 < n && tokens[i + 1].text == "[" {
            let attr_start = i;
            let (close, is_test) = scan_attribute(tokens, i);
            if is_test {
                let mut j = close + 1;
                // Skip any further attributes stacked on the same item.
                while j + 1 < n && tokens[j].text == "#" && tokens[j + 1].text == "[" {
                    j = scan_attribute(tokens, j).0 + 1;
                }
                // Consume the item: to the matching `}` of its first
                // brace, or to `;` if none opens first.
                let mut depth = 0i64;
                let mut started = false;
                let mut k = j;
                while k < n {
                    let t = tokens[k].text.as_str();
                    if t == "{" {
                        depth += 1;
                        started = true;
                    } else if t == "}" {
                        depth -= 1;
                        if started && depth == 0 {
                            k += 1;
                            break;
                        }
                    } else if t == ";" && !started && depth == 0 {
                        k += 1;
                        break;
                    }
                    k += 1;
                }
                for flag in in_test.iter_mut().take(k.min(n)).skip(attr_start) {
                    *flag = true;
                }
                i = k;
                continue;
            }
            i = close + 1;
            continue;
        }
        i += 1;
    }
    in_test
}

/// Map line -> rules suppressed on that line. An allow() comment covers
/// its own line and the next.
fn collect_suppressions(comments: &[(usize, String)]) -> BTreeMap<usize, BTreeSet<String>> {
    let mut sup: BTreeMap<usize, BTreeSet<String>> = BTreeMap::new();
    for (line, text) in comments {
        let Some(idx) = text.find("hrrlint:") else { continue };
        let rest = text[idx + "hrrlint:".len()..].trim_start();
        let Some(inner) = rest.strip_prefix("allow(") else { continue };
        let Some(close) = inner.find(')') else { continue };
        let rules: Vec<String> = inner[..close]
            .replace(',', " ")
            .split_whitespace()
            .map(|r| r.to_string())
            .collect();
        for ln in [*line, *line + 1] {
            sup.entry(ln).or_default().extend(rules.iter().cloned());
        }
    }
    sup
}

// ---------------------------------------------------------------------------
// The rule engine
// ---------------------------------------------------------------------------

/// Token text at `i`, or "" out of range (pass `i.wrapping_sub(1)` for
/// "previous token" — the wrap lands far out of range, same as the
/// Python mirror's negative-index guard).
fn tk(tokens: &[Token], i: usize) -> &str {
    tokens.get(i).map(|t| t.text.as_str()).unwrap_or("")
}

fn is_ident(tokens: &[Token], i: usize) -> bool {
    tokens.get(i).map(|t| t.kind == TokenKind::Ident).unwrap_or(false)
}

fn is_num(tokens: &[Token], i: usize) -> bool {
    tokens.get(i).map(|t| t.kind == TokenKind::Num).unwrap_or(false)
}

struct Ctx<'a> {
    path: &'a str,
    lines: Vec<&'a str>,
    in_test: Vec<bool>,
    sup: BTreeMap<usize, BTreeSet<String>>,
    findings: Vec<Finding>,
}

impl<'a> Ctx<'a> {
    fn emit(&mut self, tokens: &[Token], idx: usize, rule: &str, message: String) {
        let line = tokens[idx].line;
        if self.in_test[idx] {
            return;
        }
        if self.sup.get(&line).map(|rules| rules.contains(rule)).unwrap_or(false) {
            return;
        }
        let snippet = if line >= 1 && line <= self.lines.len() {
            self.lines[line - 1].trim().to_string()
        } else {
            String::new()
        };
        let hash = fnv1a64_hex(&format!("{rule}:{}:{snippet}", self.path));
        self.findings.push(Finding {
            file: self.path.to_string(),
            line,
            rule: rule.to_string(),
            snippet,
            message,
            hash,
            new: false,
        });
    }
}

/// Lint one file; `path` is the forward-slash path relative to the scan
/// root (scoping keys off it).
pub fn lint_source(path: &str, src: &str) -> Vec<Finding> {
    let (tokens, comments) = lex(src);
    let n = tokens.len();
    let mut ctx = Ctx {
        path,
        lines: src.split('\n').collect(),
        in_test: mark_test_regions(&tokens),
        sup: collect_suppressions(&comments),
        findings: Vec::new(),
    };

    // --- panic-path ----------------------------------------------------
    if in_panic_scope(path) {
        for i in 0..n {
            if is_ident(&tokens, i) && matches!(tk(&tokens, i), "unwrap" | "expect") {
                if tk(&tokens, i.wrapping_sub(1)) == "." && tk(&tokens, i + 1) == "(" {
                    let what = tk(&tokens, i).to_string();
                    ctx.emit(&tokens, i, "panic-path", format!("{what}() on serving path (use typed errors)"));
                }
            } else if is_ident(&tokens, i)
                && matches!(tk(&tokens, i), "panic" | "unreachable")
                && tk(&tokens, i + 1) == "!"
            {
                let what = tk(&tokens, i).to_string();
                ctx.emit(&tokens, i, "panic-path", format!("{what}! on serving path (use typed errors)"));
            }
        }
    }

    // --- wallclock-kernel ----------------------------------------------
    if in_kernel_scope(path) {
        for i in 0..n {
            if !is_ident(&tokens, i) {
                continue;
            }
            if tk(&tokens, i) == "Instant" && tk(&tokens, i + 1) == "::" && tk(&tokens, i + 2) == "now" {
                ctx.emit(&tokens, i, "wallclock-kernel", "Instant::now in deterministic kernel code".into());
            } else if tk(&tokens, i) == "SystemTime" {
                ctx.emit(&tokens, i, "wallclock-kernel", "SystemTime in deterministic kernel code".into());
            }
        }
    }

    // --- hash-iter-accum (all files) ------------------------------------
    let hash_names = collect_hash_names(&tokens);
    if !hash_names.is_empty() {
        check_hash_iteration(&tokens, &hash_names, &mut ctx);
    }

    // --- f32-accum-kernel ----------------------------------------------
    if in_kernel_scope(path) {
        check_f32_accum(&tokens, &mut ctx);
    }

    // --- unbounded-channel ---------------------------------------------
    if in_channel_scope(path) {
        for i in 0..n {
            if is_ident(&tokens, i)
                && tk(&tokens, i) == "channel"
                // `channel(` or turbofish `channel::<T>(`.
                && (tk(&tokens, i + 1) == "("
                    || (tk(&tokens, i + 1) == "::" && tk(&tokens, i + 2) == "<"))
            {
                ctx.emit(&tokens, i, "unbounded-channel", "unbounded channel() (engine mandates sync_channel)".into());
            }
        }
    }

    // --- narrow-cast-wire ----------------------------------------------
    if in_wire_scope(path) {
        for i in 0..n {
            if is_ident(&tokens, i)
                && tk(&tokens, i) == "as"
                && is_ident(&tokens, i + 1)
                && matches!(tk(&tokens, i + 1), "usize" | "u32")
            {
                let ty = tk(&tokens, i + 1).to_string();
                ctx.emit(
                    &tokens,
                    i,
                    "narrow-cast-wire",
                    format!("narrowing `as {ty}` cast in wire-facing code (use checked conversion)"),
                );
            }
        }
    }

    // --- lock-order ----------------------------------------------------
    if in_lock_scope(path) {
        check_lock_order(&tokens, &mut ctx);
    }

    // --- debug-macro ---------------------------------------------------
    if in_debug_scope(path) {
        for i in 0..n {
            if is_ident(&tokens, i)
                && matches!(tk(&tokens, i), "todo" | "dbg" | "println")
                && tk(&tokens, i + 1) == "!"
            {
                let what = tk(&tokens, i).to_string();
                ctx.emit(&tokens, i, "debug-macro", format!("{what}! outside main/bench (remove before merge)"));
            }
        }
    }

    ctx.findings
}

/// Names of variables/fields whose type mentions HashMap/HashSet: walk
/// back from the type ident to the nearest `:` annotation (field or
/// typed let), else to a `let [mut] name =` binding.
fn collect_hash_names(tokens: &[Token]) -> Vec<String> {
    let n = tokens.len();
    let mut names: Vec<String> = Vec::new();
    for i in 0..n {
        if !(tokens[i].kind == TokenKind::Ident && matches!(tokens[i].text.as_str(), "HashMap" | "HashSet")) {
            continue;
        }
        let mut name = String::new();
        let mut j = i as i64 - 1;
        while j >= 0 {
            let text = tokens[j as usize].text.as_str();
            if matches!(text, ";" | "{" | "}") {
                break;
            }
            if text == ":" {
                if j >= 1 && tokens[(j - 1) as usize].kind == TokenKind::Ident {
                    name = tokens[(j - 1) as usize].text.clone();
                }
                break;
            }
            if text == "=" {
                let mut k = j - 1;
                while k >= 0 {
                    let t2 = tokens[k as usize].text.as_str();
                    if matches!(t2, ";" | "{" | "}") {
                        break;
                    }
                    if tokens[k as usize].kind == TokenKind::Ident
                        && t2 != "mut"
                        && k >= 1
                        && matches!(tokens[(k - 1) as usize].text.as_str(), "let" | "mut")
                    {
                        name = t2.to_string();
                        break;
                    }
                    k -= 1;
                }
                break;
            }
            j -= 1;
        }
        if !name.is_empty() && !names.contains(&name) {
            names.push(name);
        }
    }
    names
}

const HASH_ITER_MESSAGE: &str = "hash-order iteration feeds an accumulation (nondeterministic order)";

fn check_hash_iteration(tokens: &[Token], hash_names: &[String], ctx: &mut Ctx) {
    let n = tokens.len();
    // (a) `for ... in <hash_name>... {` whose body accumulates.
    for i in 0..n {
        if !(is_ident(tokens, i) && tk(tokens, i) == "for") {
            continue;
        }
        // Header: tokens up to the body `{` at bracket depth 0.
        let mut depth = 0i64;
        let mut j = i + 1;
        let mut header_hit = false;
        while j < n {
            let t = tk(tokens, j);
            if matches!(t, "(" | "[") {
                depth += 1;
            } else if matches!(t, ")" | "]") {
                depth -= 1;
            } else if t == "{" && depth == 0 {
                break;
            } else if t == ";" {
                j = n; // not a for-loop header (e.g. `for` in a macro)
                break;
            } else if is_ident(tokens, j) && hash_names.iter().any(|h| h == t) {
                header_hit = true;
            }
            j += 1;
        }
        if j >= n || !header_hit {
            continue;
        }
        // Body: to the matching `}`.
        let mut bdepth = 0i64;
        let mut k = j;
        let mut accum = false;
        while k < n {
            let t = tk(tokens, k);
            if t == "{" {
                bdepth += 1;
            } else if t == "}" {
                bdepth -= 1;
                if bdepth == 0 {
                    break;
                }
            } else if t == "+=" {
                accum = true;
            } else if t == "."
                && is_ident(tokens, k + 1)
                && matches!(tk(tokens, k + 1), "push" | "extend")
                && tk(tokens, k + 2) == "("
            {
                accum = true;
            }
            k += 1;
        }
        if accum {
            ctx.emit(tokens, i, "hash-iter-accum", HASH_ITER_MESSAGE.into());
        }
    }
    // (b) `<hash_name>.iter()...collect/fold/sum` chains.
    for i in 0..n {
        if is_ident(tokens, i) && hash_names.iter().any(|h| h == tk(tokens, i)) && tk(tokens, i + 1) == "." {
            if is_ident(tokens, i + 2)
                && matches!(tk(tokens, i + 2), "iter" | "keys" | "values" | "drain" | "into_iter")
            {
                let mut j = i + 3;
                while j < n && tk(tokens, j) != ";" {
                    if is_ident(tokens, j) && matches!(tk(tokens, j), "collect" | "fold" | "sum") {
                        ctx.emit(tokens, i, "hash-iter-accum", HASH_ITER_MESSAGE.into());
                        break;
                    }
                    j += 1;
                }
            }
        }
    }
}

fn check_f32_accum(tokens: &[Token], ctx: &mut Ctx) {
    let n = tokens.len();
    // f32-typed bindings: `let [mut] name: f32` or `= <num ending f32>`.
    let mut f32_names: Vec<String> = Vec::new();
    for i in 0..n {
        if !(is_ident(tokens, i) && tk(tokens, i) == "let") {
            continue;
        }
        let mut j = i + 1;
        if tk(tokens, j) == "mut" {
            j += 1;
        }
        if !is_ident(tokens, j) {
            continue;
        }
        let name = tk(tokens, j).to_string();
        let typed = tk(tokens, j + 1) == ":" && tk(tokens, j + 2) == "f32";
        let suffixed = tk(tokens, j + 1) == "=" && is_num(tokens, j + 2) && tk(tokens, j + 2).ends_with("f32");
        if (typed || suffixed) && !f32_names.contains(&name) {
            f32_names.push(name);
        }
    }
    if f32_names.is_empty() {
        return;
    }
    // Loop-depth brace tracking: fire on `name +=` inside any loop body.
    let mut brace_is_loop: Vec<bool> = Vec::new();
    let mut pending_loop = false;
    for i in 0..n {
        let t = tk(tokens, i);
        if is_ident(tokens, i) && matches!(t, "for" | "while" | "loop") {
            pending_loop = true;
        } else if t == "{" {
            brace_is_loop.push(pending_loop);
            pending_loop = false;
        } else if t == "}" {
            brace_is_loop.pop();
        } else if t == ";" {
            pending_loop = false;
        } else if t == "+="
            && is_ident(tokens, i.wrapping_sub(1))
            && f32_names.iter().any(|f| f == tk(tokens, i.wrapping_sub(1)))
            && brace_is_loop.iter().any(|&b| b)
        {
            ctx.emit(
                tokens,
                i - 1,
                "f32-accum-kernel",
                "f32 `+=` accumulation in a loop (use an f64 accumulator)".into(),
            );
        }
    }
}

const LOCK_ORDER_MESSAGE: &str = "ParamSlot lock and ReloadHub mutex nested in one function \
                                  (canonical order: hub -> slot; see engine/mod.rs)";

fn check_lock_order(tokens: &[Token], ctx: &mut Ctx) {
    let n = tokens.len();
    let mut i = 0usize;
    while i < n {
        if !(is_ident(tokens, i) && tk(tokens, i) == "fn" && is_ident(tokens, i + 1)) {
            i += 1;
            continue;
        }
        // Body: first `{` after the signature, to its matching `}`.
        let mut j = i + 2;
        while j < n && tk(tokens, j) != "{" && tk(tokens, j) != ";" {
            j += 1;
        }
        if j >= n || tk(tokens, j) == ";" {
            i = j + 1;
            continue;
        }
        let mut depth = 0i64;
        let mut end = j;
        while end < n {
            if tk(tokens, end) == "{" {
                depth += 1;
            } else if tk(tokens, end) == "}" {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            end += 1;
        }
        let mut first_hub: Option<usize> = None;
        let mut first_slot: Option<usize> = None;
        for k in j..(end + 1).min(n) {
            if tk(tokens, k) != "." {
                continue;
            }
            let recv = if is_ident(tokens, k.wrapping_sub(1)) { tk(tokens, k.wrapping_sub(1)) } else { "" };
            let meth = if is_ident(tokens, k + 1) { tk(tokens, k + 1) } else { "" };
            if tk(tokens, k + 2) != "(" {
                continue;
            }
            if meth == "lock" && (recv == "lock" || recv.to_lowercase().contains("hub")) {
                if first_hub.is_none() {
                    first_hub = Some(k + 1);
                }
            } else if matches!(meth, "pin" | "install" | "read" | "write")
                && recv.to_lowercase().contains("slot")
                && first_slot.is_none()
            {
                first_slot = Some(k + 1);
            }
        }
        if let (Some(h), Some(s)) = (first_hub, first_slot) {
            ctx.emit(tokens, h.max(s), "lock-order", LOCK_ORDER_MESSAGE.into());
        }
        i = end + 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_of(findings: &[Finding]) -> Vec<(String, usize)> {
        findings.iter().map(|f| (f.rule.clone(), f.line)).collect()
    }

    #[test]
    fn cfg_test_exemption() {
        let src = "pub fn live(v: Option<u32>) -> u32 { v.unwrap() }\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                   \x20   #[test]\n\
                   \x20   fn t() { None::<u32>.unwrap(); panic!(\"x\"); }\n\
                   }\n";
        assert_eq!(rules_of(&lint_source("engine/x.rs", src)), [("panic-path".to_string(), 1)]);
    }

    #[test]
    fn cfg_not_test_still_fires() {
        let src = "#[cfg(not(test))]\npub fn live(v: Option<u32>) -> u32 { v.unwrap() }\n";
        assert_eq!(rules_of(&lint_source("engine/x.rs", src)), [("panic-path".to_string(), 2)]);
    }

    #[test]
    fn suppression_same_line_and_next() {
        let src = "fn a(v: Option<u32>) -> u32 {\n    // hrrlint: allow(panic-path)\n    v.unwrap()\n}\n";
        assert!(lint_source("engine/x.rs", src).is_empty());
        let src = "fn a(v: Option<u32>) -> u32 {\n    v.unwrap() // hrrlint: allow(panic-path)\n}\n";
        assert!(lint_source("engine/x.rs", src).is_empty());
        let src = "fn a(v: Option<u32>) -> u32 {\n    v.unwrap() // hrrlint: allow(debug-macro)\n}\n";
        assert_eq!(rules_of(&lint_source("engine/x.rs", src)), [("panic-path".to_string(), 2)]);
    }

    #[test]
    fn scoping_by_path() {
        let src = "fn a(v: Option<u32>) -> u32 { v.unwrap() }\n";
        assert!(lint_source("util/other.rs", src).is_empty());
        assert_eq!(rules_of(&lint_source("stream/x.rs", src)), [("panic-path".to_string(), 1)]);
        let src = "fn k() { let t = std::time::Instant::now(); drop(t); }\n";
        assert!(lint_source("hrr/grad.rs", src).is_empty());
        assert_eq!(rules_of(&lint_source("hrr/common/x.rs", src)), [("wallclock-kernel".to_string(), 1)]);
        let src = "fn m() { println!(\"x\"); }\n";
        assert!(lint_source("main.rs", src).is_empty());
        assert!(lint_source("bench/native.rs", src).is_empty());
        assert!(lint_source("bin/hrrlint.rs", src).is_empty());
        assert_eq!(rules_of(&lint_source("model/x.rs", src)), [("debug-macro".to_string(), 1)]);
    }

    #[test]
    fn turbofish_channel() {
        let src = "fn q() { let (tx, rx) = channel::<u32>(); drop((tx, rx)); }\n";
        assert_eq!(rules_of(&lint_source("engine/x.rs", src)), [("unbounded-channel".to_string(), 1)]);
        let src = "fn q() { let (tx, rx) = sync_channel::<u32>(4); drop((tx, rx)); }\n";
        assert!(lint_source("engine/x.rs", src).is_empty());
    }

    #[test]
    fn hash_iteration_and_escape() {
        let src = "use std::collections::HashMap;\n\
                   fn s(m: &HashMap<u64, u64>) -> u64 {\n\
                   \x20   let mut t = 0u64;\n\
                   \x20   for (_k, v) in m.iter() { t += v; }\n\
                   \x20   t\n\
                   }\n";
        assert_eq!(rules_of(&lint_source("util/x.rs", src)), [("hash-iter-accum".to_string(), 4)]);
    }

    #[test]
    fn lock_order_needs_both_families() {
        let src = "fn both(hub: &H, slot: &S) { let _g = hub.lock.lock(); let _v = slot.read(); }\n";
        assert_eq!(rules_of(&lint_source("engine/x.rs", src)), [("lock-order".to_string(), 1)]);
        let src = "fn one(slot: &S) { let _v = slot.read(); }\n";
        assert!(lint_source("engine/x.rs", src).is_empty());
        // Outside engine/ the rule is out of scope.
        let src = "fn both(hub: &H, slot: &S) { let _g = hub.lock.lock(); let _v = slot.read(); }\n";
        assert!(lint_source("stream/x.rs", src).is_empty());
    }

    #[test]
    fn hash_is_content_keyed() {
        let a = lint_source("engine/x.rs", "fn a(v: Option<u32>) -> u32 { v.unwrap() }\n");
        let b = lint_source("engine/x.rs", "// shifted\n\n\nfn a(v: Option<u32>) -> u32 { v.unwrap() }\n");
        assert_ne!(a[0].line, b[0].line);
        assert_eq!(a[0].hash, b[0].hash);
    }
}
